//! Post-GA transfer-optimization pass (arXiv:2002.12115's data-region
//! hoisting, made order-aware).
//!
//! The execution engines model residency *dynamically* (`vm::Loc` — MSI
//! style: a device copy stays valid until the host writes), so the cost
//! model already pays hoisted transfers: an array that stays on one
//! destination across consecutive regions is charged once, not per
//! region. What was missing is the **static** counterpart: a per-region
//! data-region plan that says, ahead of execution, which entries are
//! real `copyin`s, which are provably `present`, which exits must
//! `copyout`, and which device writes never leave the card (`keep`).
//! The rendered directives ([`crate::analysis::plan_directives`]) and
//! the measured plan both read this result, so a rendered `present` is
//! backed by zero staged transfers at that boundary *by construction* —
//! the engines count any disagreement as
//! [`crate::vm::Outcome::presence_violations`].
//!
//! The pass is a forward abstract interpretation of the entry function
//! over a small residency lattice:
//!
//! | abstract   | meaning (per array)                                   |
//! |------------|-------------------------------------------------------|
//! | `Host`     | the host copy is valid (device copies unknown)        |
//! | `Dev(d)`   | destination `d`'s copy is valid (host unknown)        |
//! | `Both(d)`  | host *and* destination `d` are valid                  |
//! | `Unknown`  | nothing provable                                      |
//!
//! Control-flow joins take the lattice meet (keep only what every path
//! proves); loops run to a fixpoint (the lattice has height 3, so a
//! handful of trial passes converge) and a body-level `break`/`continue`
//! poisons every array the loop touches, because a mid-body exit can
//! leave residency in a state the entry/exit meet never saw. Everything
//! unprovable degrades to plain `copyin`/`copyout` — strictly
//! conservative, never wrong. `present` is the only claim with
//! execution-visible teeth, so the pass under-claims it and over-claims
//! copies.

use crate::ir::{Expr, LValue, LoopId, Program, Stmt};
use crate::libs;
use crate::vm::{ExecPlan, GpuRegion};
use std::collections::{BTreeSet, HashMap, HashSet};

/// The data-region plan for one offload region, in `copy_in`/`copy_out`
/// list order of the underlying [`GpuRegion`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegionTransfers {
    /// staged host→device at region entry
    pub copy_in: Vec<String>,
    /// proven already resident on the region's destination at entry
    pub present: Vec<String>,
    /// written on the device and later consumed by the host (or another
    /// destination), so the copy-out is eventually real
    pub copy_out: Vec<String>,
    /// written on the device but never read back — the hoisting win:
    /// no `copyout` clause is rendered for these
    pub keep: Vec<String>,
}

/// Whole-plan residency result: one [`RegionTransfers`] per offload
/// region (keyed by the region's root loop id, like
/// [`ExecPlan::regions`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TransferPlan {
    pub regions: HashMap<LoopId, RegionTransfers>,
}

impl TransferPlan {
    /// Total `present` claims across all regions (test/report helper).
    pub fn present_count(&self) -> usize {
        self.regions.values().map(|r| r.present.len()).sum()
    }
}

/// Abstract residency of one array variable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AbsLoc {
    Host,
    Dev(usize),
    Both(usize),
    Unknown,
}

impl AbsLoc {
    /// Is the copy on destination `d` provably valid?
    fn valid_on(self, d: usize) -> bool {
        matches!(self, AbsLoc::Dev(x) | AbsLoc::Both(x) if x == d)
    }

    /// Lattice meet: keep only facts both sides prove.
    fn meet(self, other: AbsLoc) -> AbsLoc {
        use AbsLoc::*;
        match (self, other) {
            (a, b) if a == b => a,
            (Both(d), Dev(e)) | (Dev(e), Both(d)) if d == e => Dev(d),
            (Both(_), Host) | (Host, Both(_)) => Host,
            // different destinations: the host copy is the only
            // candidate both sides might agree on
            (Both(_), Both(_)) => Host,
            _ => Unknown,
        }
    }
}

/// Walker state at one program point: residency per array plus pending
/// device writes (region ids whose `copy_out` has not met a
/// host-visible consumer yet). `pending` owner sets are ordered so the
/// pass output is deterministic.
#[derive(Debug, Clone, PartialEq)]
struct Snap {
    state: HashMap<String, AbsLoc>,
    pending: HashMap<String, BTreeSet<LoopId>>,
}

impl Snap {
    fn new() -> Snap {
        Snap { state: HashMap::new(), pending: HashMap::new() }
    }

    fn meet(&self, other: &Snap) -> Snap {
        let mut state = HashMap::new();
        let keys: HashSet<&String> =
            self.state.keys().chain(other.state.keys()).collect();
        for k in keys {
            let a = self.state.get(k).copied().unwrap_or(AbsLoc::Host);
            let b = other.state.get(k).copied().unwrap_or(AbsLoc::Host);
            state.insert(k.clone(), a.meet(b));
        }
        // pendings union: a write pending on either path may still need
        // its copy-out realized later
        let mut pending = self.pending.clone();
        for (k, owners) in &other.pending {
            pending.entry(k.clone()).or_default().extend(owners.iter().copied());
        }
        Snap { state, pending }
    }
}

struct Pass<'a> {
    plan: &'a ExecPlan,
    /// names known to be arrays (entry-function decls + region lists)
    arrays: HashSet<String>,
    /// names that may alias another array (`a = b`, `a = f(...)`) —
    /// permanently `Unknown`, never `present`
    poisoned: HashSet<String>,
    snap: Snap,
    /// region → set of `copy_in` names proven present, intersected
    /// across record visits (a region under a loop is classified at the
    /// loop fixpoint, which under-approximates every iteration entry)
    present: HashMap<LoopId, HashSet<String>>,
    /// (region, name) copy-outs that met a host-visible consumer
    realized: HashSet<(LoopId, String)>,
    /// recording on the final pass, off during loop fixpoint trials
    record: bool,
}

/// Compute the order-aware data-region plan for `plan` over `prog`.
///
/// Regions rooted outside the entry function (or otherwise out of the
/// walker's reach) degrade to all-`copyin`/all-`copyout` — the same
/// conservative shape the naive renderer used.
pub fn optimize(prog: &Program, plan: &ExecPlan) -> TransferPlan {
    let mut p = Pass {
        plan,
        arrays: HashSet::new(),
        poisoned: HashSet::new(),
        snap: Snap::new(),
        present: HashMap::new(),
        realized: HashSet::new(),
        record: true,
    };
    for r in plan.regions.values() {
        p.arrays.extend(r.copy_in.iter().cloned());
        p.arrays.extend(r.copy_out.iter().cloned());
    }
    if let Some(entry) = prog.entry() {
        collect_arrays(&entry.body, &mut p.arrays);
        let arrays = p.arrays.clone();
        collect_poisoned(&entry.body, &arrays, &mut p.poisoned);
        p.walk_block(&entry.body);
    }
    // assemble: partition each region's lists by what the walk proved
    let mut out = TransferPlan::default();
    for (id, r) in &plan.regions {
        let proven = p.present.get(id);
        let mut rt = RegionTransfers::default();
        for a in &r.copy_in {
            if proven.is_some_and(|s| s.contains(a)) {
                rt.present.push(a.clone());
            } else {
                rt.copy_in.push(a.clone());
            }
        }
        for a in &r.copy_out {
            // unvisited regions conservatively copy everything out
            if proven.is_none() || p.realized.contains(&(*id, a.clone())) {
                rt.copy_out.push(a.clone());
            } else {
                rt.keep.push(a.clone());
            }
        }
        out.regions.insert(*id, rt);
    }
    out
}

impl<'a> Pass<'a> {
    fn get(&self, name: &str) -> AbsLoc {
        if self.poisoned.contains(name) {
            return AbsLoc::Unknown;
        }
        self.snap.state.get(name).copied().unwrap_or(AbsLoc::Host)
    }

    fn set(&mut self, name: &str, loc: AbsLoc) {
        if !self.poisoned.contains(name) {
            self.snap.state.insert(name.to_string(), loc);
        }
    }

    /// A host-visible consumer reached `name`: any pending device write
    /// must really copy out.
    fn realize(&mut self, name: &str) {
        if let Some(owners) = self.snap.pending.remove(name) {
            if self.record {
                for r in owners {
                    self.realized.insert((r, name.to_string()));
                }
            }
        }
    }

    /// CPU-side read (mirrors `vm::host_read`): pulls a device-only
    /// copy back, so the host copy becomes valid too.
    fn host_read(&mut self, name: &str) {
        if !self.arrays.contains(name) {
            return;
        }
        self.realize(name);
        match self.get(name) {
            AbsLoc::Dev(d) => self.set(name, AbsLoc::Both(d)),
            AbsLoc::Unknown => self.set(name, AbsLoc::Host),
            _ => {}
        }
    }

    /// CPU-side write (mirrors `vm::host_write`): device copies stale.
    fn host_write(&mut self, name: &str) {
        if !self.arrays.contains(name) {
            return;
        }
        self.realize(name);
        self.set(name, AbsLoc::Host);
    }

    /// Region entry/exit (mirrors `exec_gpu_region`): classify each
    /// `copy_in` name against the pre-state, then apply the residency
    /// effects of the staged reads and the device-side writes.
    fn region(&mut self, region: &GpuRegion) {
        let dest = region.dest;
        let mut proven: HashSet<String> = HashSet::new();
        for a in &region.copy_in {
            let pre = self.get(a);
            if pre.valid_on(dest) {
                proven.insert(a.clone());
                // already resident: no transfer, no state change
                continue;
            }
            // staging from another destination goes through the host
            // (d2h from the owner first) — that d2h realizes the
            // owner's pending copy-out
            if matches!(pre, AbsLoc::Dev(_) | AbsLoc::Unknown) {
                self.realize(a);
            }
            let post = match pre {
                AbsLoc::Unknown => AbsLoc::Dev(dest),
                _ => AbsLoc::Both(dest),
            };
            self.set(a, post);
        }
        if self.record {
            match self.present.entry(region.root) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    e.get_mut().retain(|a| proven.contains(a));
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(proven);
                }
            }
        }
        for a in &region.copy_out {
            // an earlier pending write to the same array is dead on the
            // device (overwritten before it ever reached the host)
            self.snap.pending.remove(a);
            self.snap.pending.entry(a.clone()).or_default().insert(region.root);
            self.set(a, AbsLoc::Dev(dest));
        }
    }

    /// A library call replaced by a device implementation (function
    /// block): array args are read on, then conservatively written by,
    /// the call's destination.
    fn gpu_call(&mut self, name: &str, array_args: &[String]) {
        let dest = self.plan.call_dest.get(name).copied().unwrap_or(0);
        for a in array_args {
            if matches!(self.get(a), AbsLoc::Dev(_) | AbsLoc::Unknown) {
                self.realize(a);
            }
            // the write makes any earlier pending copy-out dead; the
            // call itself has no directive slot, so nothing new pends
            self.snap.pending.remove(a);
            self.set(a, AbsLoc::Dev(dest));
        }
    }

    /// Evaluate an expression on the host: every array it can touch is
    /// a host read; calls get their own models.
    fn host_expr(&mut self, e: &Expr) {
        match e {
            Expr::IntLit(_) | Expr::FloatLit(_) => {}
            Expr::Var(n) | Expr::Len { base: n, .. } => self.host_read(n),
            Expr::Index { base, indices } => {
                for i in indices {
                    self.host_expr(i);
                }
                self.host_read(base);
            }
            Expr::Binary { lhs, rhs, .. } => {
                self.host_expr(lhs);
                self.host_expr(rhs);
            }
            Expr::Unary { operand, .. } => self.host_expr(operand),
            Expr::Intrinsic { args, .. } => {
                for a in args {
                    self.host_expr(a);
                }
            }
            Expr::Call { name, args } => self.call(name, args),
        }
    }

    fn call(&mut self, name: &str, args: &[Expr]) {
        for a in args {
            // argument evaluation itself (index math etc.)
            if !matches!(a, Expr::Var(_)) {
                self.host_expr(a);
            }
        }
        let array_args: Vec<String> = args
            .iter()
            .filter_map(|a| match a {
                Expr::Var(n) if self.arrays.contains(n) => Some(n.clone()),
                _ => None,
            })
            .collect();
        if self.plan.gpu_calls.contains(name) {
            self.gpu_call(name, &array_args);
        } else if libs::is_library(name) {
            // CPU library: reads and writes every array arg on the host
            for a in &array_args {
                self.host_read(a);
                self.host_write(a);
            }
        } else {
            // user function: its body is outside this walk — assume
            // anything about the arrays it received
            for a in &array_args {
                self.realize(a);
                self.snap.state.insert(a.clone(), AbsLoc::Unknown);
            }
        }
    }

    fn walk_block(&mut self, body: &[Stmt]) {
        for s in body {
            self.walk_stmt(s);
        }
    }

    fn walk_stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl { name, dims, init, .. } => {
                for d in dims {
                    self.host_expr(d);
                }
                if let Some(e) = init {
                    self.host_expr(e);
                }
                if !dims.is_empty() {
                    // fresh array: any pending write to a shadowed name
                    // can never reach this new storage
                    self.snap.pending.remove(name);
                    self.set(name, AbsLoc::Host);
                }
            }
            Stmt::Assign { target, value, .. } => {
                self.host_expr(value);
                match target {
                    LValue::Var(n) => {
                        if self.arrays.contains(n) {
                            // rebinding an array name (aliasing) — the
                            // prescan poisoned it; stay safe regardless
                            self.realize(n);
                            self.snap.state.insert(n.clone(), AbsLoc::Unknown);
                        }
                    }
                    LValue::Index { base, indices } => {
                        for i in indices {
                            self.host_expr(i);
                        }
                        self.host_write(base);
                    }
                }
            }
            Stmt::For { id, start, end, step, body, .. } => {
                if let Some(region) = self.plan.regions.get(id) {
                    // bounds evaluate inside the region (no host reads)
                    let region = region.clone();
                    self.region(&region);
                    return;
                }
                self.host_expr(start);
                self.host_expr(end);
                self.host_expr(step);
                self.host_loop(body, None);
            }
            Stmt::While { cond, body } => {
                // the condition runs before the first iteration and
                // after every body pass
                self.host_expr(cond);
                self.host_loop(body, Some(cond));
            }
            Stmt::If { cond, then_body, else_body } => {
                self.host_expr(cond);
                let before = self.snap.clone();
                self.walk_block(then_body);
                let after_then = std::mem::replace(&mut self.snap, before);
                self.walk_block(else_body);
                self.snap = self.snap.meet(&after_then);
            }
            Stmt::Call { name, args } => self.call(name, args),
            Stmt::Return(e) => {
                if let Some(e) = e {
                    self.host_expr(e);
                }
                // fall through: statements past a return are dynamically
                // dead, so whatever we record for them is vacuous
            }
            Stmt::Break | Stmt::Continue => {}
            Stmt::Print(e) => self.host_expr(e),
        }
    }

    /// A host-level loop that may contain region roots: run the body
    /// transfer function to a fixpoint (trial passes, no recording),
    /// then record from the fixpoint state, which under-approximates
    /// every dynamic iteration entry. A body-level `break`/`continue`
    /// invalidates the entry/exit meet (a mid-body exit can escape with
    /// residency neither endpoint saw), so every array the loop touches
    /// is poisoned to `Unknown` instead.
    fn host_loop(&mut self, body: &[Stmt], cond: Option<&Expr>) {
        let entry = self.snap.clone();
        let mut cur = entry.clone();
        let was_recording = self.record;
        self.record = false;
        for _ in 0..8 {
            self.snap = cur.clone();
            self.walk_block(body);
            if let Some(c) = cond {
                self.host_expr(c);
            }
            let next = cur.meet(&self.snap);
            if next == cur {
                break;
            }
            cur = next;
        }
        self.record = was_recording;
        if has_own_break_or_continue(body) {
            let mut touched = HashSet::new();
            collect_arrays_mentioned(body, &self.arrays, &mut touched);
            for a in touched {
                cur.state.insert(a, AbsLoc::Unknown);
            }
        }
        // record from the fixpoint; walk twice so a pending created late
        // in the body meets its consumer early in the next iteration
        self.snap = cur.clone();
        self.walk_block(body);
        if let Some(c) = cond {
            self.host_expr(c);
        }
        self.walk_block(body);
        if let Some(c) = cond {
            self.host_expr(c);
        }
        // the loop may run zero times
        self.snap = self.snap.meet(&entry);
    }
}

/// `break`/`continue` belonging to this loop body (not to a loop nested
/// inside it).
fn has_own_break_or_continue(body: &[Stmt]) -> bool {
    body.iter().any(|s| match s {
        Stmt::Break | Stmt::Continue => true,
        Stmt::If { then_body, else_body, .. } => {
            has_own_break_or_continue(then_body) || has_own_break_or_continue(else_body)
        }
        _ => false,
    })
}

fn collect_arrays(body: &[Stmt], out: &mut HashSet<String>) {
    for s in body {
        match s {
            Stmt::Decl { name, dims, .. } if !dims.is_empty() => {
                out.insert(name.clone());
            }
            Stmt::For { body, .. } | Stmt::While { body, .. } => collect_arrays(body, out),
            Stmt::If { then_body, else_body, .. } => {
                collect_arrays(then_body, out);
                collect_arrays(else_body, out);
            }
            _ => {}
        }
    }
}

/// Array names mentioned anywhere under `body` (for loop poisoning).
fn collect_arrays_mentioned(body: &[Stmt], arrays: &HashSet<String>, out: &mut HashSet<String>) {
    let mut note_expr = |e: &Expr, out: &mut HashSet<String>| {
        let mut vs = Vec::new();
        e.collect_vars(&mut vs);
        out.extend(vs.into_iter().filter(|v| arrays.contains(v)));
    };
    for s in body {
        match s {
            Stmt::Decl { name, dims, init, .. } => {
                if !dims.is_empty() {
                    out.insert(name.clone());
                }
                for d in dims {
                    note_expr(d, out);
                }
                if let Some(e) = init {
                    note_expr(e, out);
                }
            }
            Stmt::Assign { target, value, .. } => {
                if arrays.contains(target.base_name()) {
                    out.insert(target.base_name().to_string());
                }
                if let LValue::Index { indices, .. } = target {
                    for i in indices {
                        note_expr(i, out);
                    }
                }
                note_expr(value, out);
            }
            Stmt::For { start, end, step, body, .. } => {
                note_expr(start, out);
                note_expr(end, out);
                note_expr(step, out);
                collect_arrays_mentioned(body, arrays, out);
            }
            Stmt::While { cond, body } => {
                note_expr(cond, out);
                collect_arrays_mentioned(body, arrays, out);
            }
            Stmt::If { cond, then_body, else_body } => {
                note_expr(cond, out);
                collect_arrays_mentioned(then_body, arrays, out);
                collect_arrays_mentioned(else_body, arrays, out);
            }
            Stmt::Call { args, .. } => {
                for a in args {
                    note_expr(a, out);
                }
            }
            Stmt::Return(Some(e)) | Stmt::Print(e) => note_expr(e, out),
            _ => {}
        }
    }
}

/// Names that may alias an array: `x = y` with `y` an array, or
/// `x = f(...)` (the callee may return one of its array arguments). Both
/// sides are poisoned for the whole walk — aliases would let a write
/// through one name invalidate residency tracked under another.
fn collect_poisoned(body: &[Stmt], arrays: &HashSet<String>, out: &mut HashSet<String>) {
    let mut note_rhs = |name: &str, e: &Expr, out: &mut HashSet<String>| match e {
        Expr::Var(v) if arrays.contains(v) => {
            out.insert(name.to_string());
            out.insert(v.clone());
        }
        Expr::Call { args, .. } => {
            out.insert(name.to_string());
            for a in args {
                if let Expr::Var(v) = a {
                    if arrays.contains(v) {
                        out.insert(v.clone());
                    }
                }
            }
        }
        _ => {}
    };
    for s in body {
        match s {
            Stmt::Decl { name, dims, init: Some(e), .. } if dims.is_empty() => {
                note_rhs(name, e, out);
            }
            Stmt::Assign { target: LValue::Var(n), value, .. } => note_rhs(n, value, out),
            Stmt::For { body, .. } | Stmt::While { body, .. } => {
                collect_poisoned(body, arrays, out);
            }
            Stmt::If { then_body, else_body, .. } => {
                collect_poisoned(then_body, arrays, out);
                collect_poisoned(else_body, arrays, out);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{analyze, build_plan};
    use crate::frontend::parse;
    use crate::ir::Lang;

    fn pass_for(src: &str, gene: &[bool]) -> (Program, ExecPlan, TransferPlan) {
        let p = parse(src, Lang::C, "t").unwrap();
        let a = analyze(&p);
        assert_eq!(a.gene_loops().len(), gene.len(), "gene length");
        let plan = build_plan(&a, gene, false);
        let tp = optimize(&p, &plan);
        (p, plan, tp)
    }

    use crate::ir::Program;

    #[test]
    fn chained_same_destination_regions_stay_resident() {
        let (_, plan, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int i = 0; i < n; i++) { x[i] = i; }
                for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                for (int i = 0; i < n; i++) { x[i] = y[i] + 1.0; }
            }"#,
            &[true, true, true],
        );
        assert_eq!(plan.regions.len(), 3);
        // region 1 reads x written by region 0: present
        assert_eq!(tp.regions[&1].present, vec!["x".to_string()]);
        assert!(tp.regions[&1].copy_in.is_empty());
        // region 2 reads y written by region 1: present
        assert_eq!(tp.regions[&2].present, vec!["y".to_string()]);
        // nothing is ever read on the host: every device write keeps
        for id in [0usize, 1, 2] {
            assert!(tp.regions[&id].copy_out.is_empty(), "region {id} copies out");
        }
    }

    #[test]
    fn host_write_between_regions_blocks_present() {
        // the order-aware regression case: both regions touch x on the
        // same destination, but the host writes x between them, so the
        // second region must copy in, not claim `present`
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                x[0] = y[0] + 3.0;
                for (int i = 0; i < n; i++) { y[i] = x[i] * 0.5 + y[i]; }
            }"#,
            &[true, true],
        );
        assert_eq!(tp.regions[&0].copy_in, vec!["x".to_string()]);
        assert_eq!(tp.regions[&1].copy_in, vec!["x".to_string()], "host wrote x in between");
        // y's device copy stays valid across the host *read* of y[0]
        assert_eq!(tp.regions[&1].present, vec!["y".to_string()]);
        // the host read of y[0] realizes region 0's copy-out
        assert_eq!(tp.regions[&0].copy_out, vec!["y".to_string()]);
    }

    #[test]
    fn host_read_after_region_realizes_copy_out() {
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n];
                for (int i = 0; i < n; i++) { x[i] = i * 2.0; }
                printf("%f\n", x[3]);
            }"#,
            &[true],
        );
        assert_eq!(tp.regions[&0].copy_out, vec!["x".to_string()]);
        assert!(tp.regions[&0].keep.is_empty());
    }

    #[test]
    fn unread_device_write_is_kept() {
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                printf("%f\n", x[0]);
            }"#,
            &[true],
        );
        // y is written on the device and never consumed again
        assert_eq!(tp.regions[&0].keep, vec!["y".to_string()]);
        assert!(tp.regions[&0].copy_out.is_empty());
    }

    #[test]
    fn region_under_host_loop_is_classified_at_the_fixpoint() {
        // iteration 1 enters the region with x host-resident; later
        // iterations enter with x device-resident — `present` would be
        // wrong for the first pass, so the fixpoint must reject it
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int t = 0; t < 4; t++) {
                    for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                    x[0] = t;
                }
                printf("%f\n", y[0]);
            }"#,
            &[true],
        );
        let only = tp.regions.values().next().unwrap();
        assert!(only.present.is_empty(), "{only:?}");
        assert_eq!(only.copy_in, vec!["x".to_string()]);
    }

    #[test]
    fn region_under_host_loop_with_stable_input_is_present() {
        // x is never invalidated between iterations: after the first
        // upload it stays resident, and the fixpoint proves it
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int i = 0; i < n; i++) { x[i] = i; }
                for (int t = 0; t < 4; t++) {
                    for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                }
                printf("%f\n", y[0]);
            }"#,
            &[true, true],
        );
        // the seed region leaves x device-resident, the swept region
        // reuses it every iteration
        let swept = tp
            .regions
            .iter()
            .find(|(_, r)| !r.copy_in.contains(&"x".to_string()) || !r.present.is_empty())
            .map(|(_, r)| r)
            .unwrap();
        assert_eq!(swept.present, vec!["x".to_string()], "{tp:?}");
    }

    #[test]
    fn break_in_host_loop_poisons_residency() {
        // a mid-body break can exit with x freshly host-written while
        // the entry/exit meet claims device residency — the pass must
        // refuse `present` on the trailing region
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int i = 0; i < n; i++) { x[i] = i; }
                int t = 0;
                while (t < 5) {
                    for (int i = 0; i < n; i++) { x[i] = x[i] + 1.0; }
                    if (t > 2) { break; }
                    t = t + 1;
                }
                for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                printf("%f\n", y[0]);
            }"#,
            &[true, true, true],
        );
        // the last region (reads x) must not claim present
        let last = tp
            .regions
            .iter()
            .find(|(_, r)| r.copy_out.contains(&"y".to_string()) || r.keep.contains(&"y".to_string()))
            .map(|(_, r)| r)
            .unwrap();
        assert!(last.present.is_empty(), "{last:?}");
    }

    #[test]
    fn if_branches_meet_conservatively() {
        // x device-resident on one branch only: the join must not prove
        // residency for the trailing region
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                int c = 1;
                if (c > 0) {
                    for (int i = 0; i < n; i++) { x[i] = i; }
                } else {
                    x[0] = 1.0;
                }
                for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                printf("%f\n", y[0]);
            }"#,
            &[true, true],
        );
        let trailing = tp
            .regions
            .iter()
            .find(|(_, r)| {
                r.copy_in.contains(&"x".to_string()) || r.present.contains(&"x".to_string())
            })
            .map(|(_, r)| r)
            .unwrap();
        assert!(trailing.present.is_empty(), "{trailing:?}");
    }

    #[test]
    fn user_call_with_array_arg_degrades_to_unknown() {
        let (_, _, tp) = pass_for(
            r#"void main() {
                int n = 8;
                double x[n]; double y[n];
                for (int i = 0; i < n; i++) { x[i] = i; }
                touch(x, n);
                for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0; }
                printf("%f\n", y[0]);
            }
            void touch(double a[], int n) {
                a[0] = 7.0;
            }"#,
            &[true, true],
        );
        let trailing = tp
            .regions
            .iter()
            .find(|(_, r)| {
                r.copy_in.contains(&"x".to_string()) || r.present.contains(&"x".to_string())
            })
            .map(|(_, r)| r)
            .unwrap();
        assert!(trailing.present.is_empty(), "callee may touch x on the host");
        // and the callee's host access realizes the seed region's write
        let seed = tp
            .regions
            .iter()
            .find(|(_, r)| r.copy_out.contains(&"x".to_string()))
            .map(|(_, r)| r);
        assert!(seed.is_some(), "{tp:?}");
    }

    #[test]
    fn cross_destination_consumption_realizes_copy_out() {
        use crate::device::TargetKind;
        use crate::placement::DeviceSet;
        let p = parse(
            r#"void main() {
                int n = 8;
                double x[n];
                for (int i = 0; i < n; i++) { x[i] = i; }
                for (int i = 0; i < n; i++) { x[i] = x[i] * 2.0; }
            }"#,
            Lang::C,
            "t",
        )
        .unwrap();
        let a = analyze(&p);
        let set = DeviceSet::new(vec![TargetKind::Gpu, TargetKind::Fpga]).unwrap();
        let plan = crate::placement::build_plan(
            &a,
            &set,
            &[Some(TargetKind::Gpu), Some(TargetKind::Fpga)],
            false,
        );
        let tp = optimize(&p, &plan);
        // staging x to the FPGA pulls it off the GPU: region 0 copies out
        assert_eq!(tp.regions[&0].copy_out, vec!["x".to_string()]);
        assert_eq!(tp.regions[&1].copy_in, vec!["x".to_string()]);
        assert!(tp.regions[&1].present.is_empty());
    }

    #[test]
    fn aliased_arrays_are_poisoned() {
        let p = parse(
            r#"void main() {
                int n = 8;
                double x[n];
                for (int i = 0; i < n; i++) { x[i] = i; }
                for (int i = 0; i < n; i++) { x[i] = x[i] * 2.0; }
            }"#,
            Lang::C,
            "t",
        )
        .unwrap();
        // hand-poison via a synthetic alias statement is hard to parse
        // from C; exercise collect_poisoned directly
        let mut arrays = HashSet::new();
        arrays.insert("x".to_string());
        let body = vec![Stmt::Assign {
            target: LValue::Var("b".to_string()),
            op: crate::ir::AssignOp::Set,
            value: Expr::Var("x".to_string()),
        }];
        let mut poisoned = HashSet::new();
        collect_poisoned(&body, &arrays, &mut poisoned);
        assert!(poisoned.contains("x") && poisoned.contains("b"));
        // a poisoned array never proves present
        let a = analyze(&p);
        let plan = build_plan(&a, &[true, true], false);
        let mut pass = Pass {
            plan: &plan,
            arrays: arrays.clone(),
            poisoned,
            snap: Snap::new(),
            present: HashMap::new(),
            realized: HashSet::new(),
            record: true,
        };
        pass.walk_block(&p.entry().unwrap().body);
        assert!(pass.present.values().all(|s| s.is_empty()), "{:?}", pass.present);
    }

    #[test]
    fn meet_is_commutative_and_sound() {
        use AbsLoc::*;
        let all = [Host, Dev(0), Dev(1), Both(0), Both(1), Unknown];
        for a in all {
            for b in all {
                assert_eq!(a.meet(b), b.meet(a), "{a:?} {b:?}");
                // meet never proves device validity one side lacks
                for d in [0usize, 1] {
                    if a.meet(b).valid_on(d) {
                        assert!(a.valid_on(d) && b.valid_on(d), "{a:?} {b:?} {d}");
                    }
                }
            }
        }
    }
}
