//! Function-block offload (§3.2.2, §4.2.1, [40]).
//!
//! The paper's second — and usually stronger — offload mechanism: find
//! function blocks that have a device-tuned implementation in the pattern
//! DB and replace them, measuring each replacement (and combinations) in
//! the verification environment. Discovery is two-pronged:
//!
//! 1. **Name match** — calls to known host libraries (`matmul`, `dft`, ...)
//!    are replaced by the GPU library (CUDA-library analogue → our
//!    Pallas/XLA artifacts via PJRT).
//! 2. **Clone similarity** — hand-written loop nests that Deckard-style
//!    vectors match against the DB's comparison code are *structurally
//!    verified* (argument extraction) and replaced by a GPU library call.
//!    When the structural interface cannot be matched the paper asks the
//!    user; `FuncBlockConfig::auto_approve_interface=false` models a
//!    declining user (candidate skipped).

use crate::analysis::ProgramAnalysis;
use crate::clone::{char_vector_stmt, similarity};
use crate::config::FuncBlockConfig;
use crate::device::TargetKind;
use crate::engine::MeasurementEngine;
use crate::ir::*;
use crate::measure::Measurement;
use crate::patterndb::PatternDb;
use crate::placement::DeviceSet;
use crate::vm::{ExecPlan, GpuRegion, RegionExec};
use std::collections::HashSet;

/// How a candidate replaces code.
#[derive(Debug, Clone, PartialEq)]
pub enum CandidateKind {
    /// all calls to this host library go to the GPU library
    NameMatch { lib: String },
    /// a clone-detected loop nest is replaced by a GPU library call
    CloneNest { root: LoopId, kernel: String, args: Vec<String>, score: f64 },
}

#[derive(Debug, Clone)]
pub struct Candidate {
    pub kind: CandidateKind,
    pub description: String,
}

impl Candidate {
    /// Loop ids swallowed by this candidate (excluded from the loop GA —
    /// §4.2: ループ文オフロードは…機能ブロック部分を抜いたコードに対して試行).
    pub fn swallowed_loops(&self, analysis: &ProgramAnalysis) -> HashSet<LoopId> {
        match &self.kind {
            CandidateKind::NameMatch { .. } => HashSet::new(),
            CandidateKind::CloneNest { root, .. } => {
                let mut out = HashSet::new();
                let mut stack = vec![*root];
                while let Some(id) = stack.pop() {
                    out.insert(id);
                    stack.extend(&analysis.loops[id].children);
                }
                out
            }
        }
    }
}

/// Find all function-block candidates in a program.
pub fn find_candidates(
    prog: &Program,
    analysis: &ProgramAnalysis,
    db: &PatternDb,
    cfg: &FuncBlockConfig,
) -> Vec<Candidate> {
    let mut out = Vec::new();
    // 1. name matches — one candidate per distinct library called
    for name in analysis.library_names_called() {
        if let Some(rec) = db.lookup_name(&name) {
            out.push(Candidate {
                kind: CandidateKind::NameMatch { lib: name.clone() },
                description: format!("library call `{name}` → GPU {}", rec.description),
            });
        }
    }
    // 2. clone similarity over loop nests
    for info in &analysis.loops {
        // only consider outermost candidates; nested roots are reached via
        // their own ids if the outer doesn't match
        let Some(stmt) = prog.find_for(info.id) else { continue };
        let v = char_vector_stmt(stmt);
        if let Some((rec, score)) = db_lookup(db, &v, cfg.clone_threshold) {
            // structural verification: can we actually bind the interface?
            let extraction = match rec.key.as_str() {
                "matmul" => extract_matmul(stmt),
                "jacobi_step" => extract_jacobi(stmt),
                _ => None,
            };
            match extraction {
                Some(args) if cfg.auto_approve_interface => {
                    out.push(Candidate {
                        kind: CandidateKind::CloneNest {
                            root: info.id,
                            kernel: rec.key.clone(),
                            args,
                            score,
                        },
                        description: format!(
                            "loop nest @{} ≈ {} (similarity {score:.3}) → GPU library",
                            info.id, rec.key
                        ),
                    });
                }
                _ => {} // interface mismatch or user declined
            }
        }
    }
    // drop clone candidates nested inside another clone candidate
    let roots: Vec<LoopId> = out
        .iter()
        .filter_map(|c| match &c.kind {
            CandidateKind::CloneNest { root, .. } => Some(*root),
            _ => None,
        })
        .collect();
    out.retain(|c| match &c.kind {
        CandidateKind::CloneNest { root, .. } => !roots.iter().any(|&r| {
            r != *root && {
                let mut anc = analysis.loops[*root].parent;
                let mut found = false;
                while let Some(a) = anc {
                    if a == r {
                        found = true;
                        break;
                    }
                    anc = analysis.loops[a].parent;
                }
                found
            }
        }),
        _ => true,
    });
    out
}

fn db_lookup<'a>(
    db: &'a PatternDb,
    v: &crate::clone::CharVec,
    threshold: f64,
) -> Option<(&'a crate::patterndb::PatternRecord, f64)> {
    let mut best: Option<(&crate::patterndb::PatternRecord, f64)> = None;
    for r in db.records() {
        if r.vector.iter().all(|&x| x == 0.0) {
            continue;
        }
        let s = similarity(v, &r.vector);
        if s >= threshold && best.map(|(_, bs)| s > bs).unwrap_or(true) {
            best = Some((r, s));
        }
    }
    best
}

/// Apply a chosen candidate set to a plan, each candidate on its
/// destination (an index into the plan's device set; 0 = primary).
pub fn apply(plan: &mut ExecPlan, analysis: &ProgramAnalysis, chosen: &[(&Candidate, usize)]) {
    for (c, dest) in chosen {
        match &c.kind {
            CandidateKind::NameMatch { lib } => {
                plan.gpu_calls.insert(lib.clone());
                plan.call_dest.insert(lib.clone(), *dest);
            }
            CandidateKind::CloneNest { root, kernel, args, .. } => {
                let info = &analysis.loops[*root];
                let mut copy_in: Vec<String> = info.array_reads.iter().cloned().collect();
                let mut copy_out: Vec<String> = info.array_writes.iter().cloned().collect();
                copy_in.sort();
                copy_out.sort();
                plan.regions.insert(
                    *root,
                    GpuRegion {
                        root: *root,
                        copy_in,
                        copy_out,
                        exec: RegionExec::Library { name: kernel.clone(), args: args.clone() },
                        dest: *dest,
                    },
                );
            }
        }
    }
}

/// Result of the function-block trial phase.
#[derive(Debug, Clone)]
pub struct FuncBlockReport {
    pub candidates: Vec<Candidate>,
    /// indices into `candidates` of the winning assignment (candidates
    /// placed on any destination)
    pub chosen: Vec<usize>,
    /// destination of each chosen candidate, aligned with `chosen`
    pub dests: Vec<TargetKind>,
    pub best: Measurement,
    /// measurements per trial: (assignment index in mixed-radix
    /// `device-count + 1` digits, fitness score)
    pub trials: Vec<(u64, f64)>,
}

/// The candidate-assignment → plan mapping for [`trial_combinations`]: a
/// placement-gene with one [`DeviceSet`] slot per candidate (one bit per
/// candidate in the single-destination case). Shared with the measurement
/// engine's pool workers, so it is a `Sync` closure over borrowed
/// analysis data — pass it to [`MeasurementEngine::new`] as the plan
/// builder.
pub fn mask_plan<'a>(
    analysis: &'a ProgramAnalysis,
    candidates: &'a [Candidate],
    set: &'a DeviceSet,
    naive_transfers: bool,
) -> impl Fn(&[bool]) -> ExecPlan + Sync + 'a {
    move |mask: &[bool]| {
        // trial_combinations caps the mask at 16 slots, so derive the
        // slot count from the mask itself (candidates beyond it stay off)
        let slots = mask.len() / set.bits_per_slot();
        debug_assert!(slots <= candidates.len());
        let placement = set.decode(mask, slots);
        let chosen: Vec<(&Candidate, usize)> = placement
            .iter()
            .enumerate()
            .filter_map(|(i, p)| p.map(|t| (&candidates[i], set.index_of(t).unwrap_or(0))))
            .collect();
        let mut plan = ExecPlan {
            naive_transfers,
            devices: set.devices().to_vec(),
            ..Default::default()
        };
        apply(&mut plan, analysis, &chosen);
        plan
    }
}

/// Measure candidate destination assignments (the paper's on/off +
/// combination trials, generalized to "off or any destination" per
/// candidate) and keep the fastest. Assignment 0 (pure CPU) is always
/// included, so the phase never regresses. All assignments go to the
/// engine as one batch, so the pool measures them concurrently; the
/// winner is then re-verified on the engine's serial device to recover
/// its full [`Measurement`].
///
/// The engine's plan builder must be [`mask_plan`] over the same
/// `candidates` slice and [`DeviceSet`] (same order).
/// The assignment indices one trial phase measures: all `arity^k`
/// mixed-radix combos when they fit the budget; otherwise the empty
/// assignment, then the single-candidate × destination assignments, then
/// the sequential prefix — all cut off at the budget. Spending the
/// budget on the coverage tier first means no candidate is starved by
/// prefix truncation as long as the budget admits the `1 + k·(arity−1)`
/// singles (the default budget of 64 covers the full 16 × 3 worst case);
/// below that, the budget itself is the bound and earlier candidates
/// win. Deterministic, duplicate-free, and identical to the plain
/// `0..total` enumeration whenever the budget is not exceeded (in
/// particular: always, for the default budget with a single destination
/// and ≤ 6 candidates).
fn trial_assignments(k: usize, arity: u64, budget: u64) -> Vec<u64> {
    let total = arity.checked_pow(k as u32).unwrap_or(u64::MAX);
    if total <= budget {
        return (0..total).collect();
    }
    let mut out: Vec<u64> = vec![0]; // the CPU-only assignment
    let mut seen: std::collections::HashSet<u64> = out.iter().copied().collect();
    let push = |out: &mut Vec<u64>, seen: &mut std::collections::HashSet<u64>, c: u64| {
        if out.len() < budget as usize && seen.insert(c) {
            out.push(c);
        }
    };
    // coverage tier: candidate i alone on destination v
    for i in 0..k {
        let place = arity.pow(i as u32);
        for v in 1..arity {
            push(&mut out, &mut seen, v * place);
        }
    }
    // fill the rest of the budget with the sequential prefix
    let mut c = 1u64;
    while out.len() < budget as usize && c < total {
        push(&mut out, &mut seen, c);
        c += 1;
    }
    out
}

pub fn trial_combinations(
    candidates: &[Candidate],
    set: &DeviceSet,
    engine: &mut MeasurementEngine<'_>,
    cfg: &FuncBlockConfig,
) -> FuncBlockReport {
    let k = candidates.len().min(16);
    let arity = (set.len() + 1) as u64; // off + one per destination
    // arity ≤ 4 and k ≤ 16 keep arity^k within u64; the trial budget is
    // what actually bounds the enumeration
    let combos = trial_assignments(k, arity, cfg.max_combination_trials.max(1) as u64);
    let bits = set.bits_per_slot();
    let masks: Vec<Vec<bool>> = combos
        .iter()
        .map(|&combo| {
            // mixed-radix digits, least-significant candidate first —
            // with one destination this is exactly the old bitmask order
            let mut gene = vec![false; k * bits];
            let mut x = combo;
            for slot in 0..k {
                let v = (x % arity) as usize;
                x /= arity;
                for i in 0..bits {
                    gene[slot * bits + i] = v >> i & 1 == 1;
                }
            }
            gene
        })
        .collect();
    let times = engine.measure_batch(&masks);

    let mut best_idx = 0usize;
    for (i, &t) in times.iter().enumerate() {
        if t < times[best_idx] {
            best_idx = i;
        }
    }
    let trials: Vec<(u64, f64)> =
        combos.iter().zip(&times).map(|(&c, &t)| (c, t)).collect();
    let best: Measurement = engine.measure_full(&masks[best_idx]);
    let placement = set.decode(&masks[best_idx], k);
    let mut chosen = Vec::new();
    let mut dests = Vec::new();
    for (i, p) in placement.iter().enumerate() {
        if let Some(t) = p {
            chosen.push(i);
            dests.push(*t);
        }
    }
    FuncBlockReport { candidates: candidates.to_vec(), chosen, dests, best, trials }
}

// ---------------------------------------------------------------------------
// structural interface extraction
// ---------------------------------------------------------------------------

/// Match a canonical matmul nest and extract `(a, b, c, n)` variable names:
/// ```text
/// for i in 0..n: for j in 0..n: { s = 0; for k in 0..n: s += a[i][k]*b[k][j]; c[i][j] = s }
/// ```
pub fn extract_matmul(stmt: &Stmt) -> Option<Vec<String>> {
    let Stmt::For { var: vi, end: end_i, body: bi, .. } = stmt else { return None };
    let n1 = var_name(end_i)?;
    let [Stmt::For { var: vj, end: end_j, body: bj, .. }] = bi.as_slice() else { return None };
    if var_name(end_j)? != n1 {
        return None;
    }
    // body: Decl s = 0; For k { s += a[i][k] * b[k][j] }; c[i][j] = s
    let [Stmt::Decl { name: s_name, .. }, Stmt::For { var: vk, end: end_k, body: bk, .. }, Stmt::Assign { target: LValue::Index { base: c, indices: c_idx }, op: AssignOp::Set, value: rhs }] =
        bj.as_slice()
    else {
        return None;
    };
    if var_name(end_k)? != n1 {
        return None;
    }
    if !matches!(rhs, Expr::Var(v) if v == s_name) {
        return None;
    }
    if !(index_is(c_idx, vi, vj)) {
        return None;
    }
    // s += <expr involving a[i][k] * b[k][j]> (allow scaling later? keep strict)
    let [Stmt::Assign { target: LValue::Var(acc), op, value }] = bk.as_slice() else { return None };
    if acc != s_name || !matches!(op, AssignOp::Add) {
        return None;
    }
    let Expr::Binary { op: BinOp::Mul, lhs, rhs } = value else { return None };
    let (a, b) = match (&**lhs, &**rhs) {
        (
            Expr::Index { base: a, indices: ai },
            Expr::Index { base: b, indices: bi_ },
        ) => {
            if index_is(ai, vi, vk) && index_is(bi_, vk, vj) {
                (a.clone(), b.clone())
            } else if index_is(bi_, vi, vk) && index_is(ai, vk, vj) {
                (b.clone(), a.clone())
            } else {
                return None;
            }
        }
        _ => return None,
    };
    Some(vec![a, b, c.clone(), n1])
}

/// Match an interior 5-point Jacobi sweep and extract `(src, dst, n, m)`:
/// ```text
/// for i in 1..n-1: for j in 1..m-1: dst[i][j] = 0.25*(src[i-1][j]+src[i+1][j]+src[i][j-1]+src[i][j+1])
/// ```
pub fn extract_jacobi(stmt: &Stmt) -> Option<Vec<String>> {
    let Stmt::For { start: st_i, end: end_i, body: bi, .. } = stmt else { return None };
    if !matches!(st_i, Expr::IntLit(1)) {
        return None;
    }
    let n = minus_one_var(end_i)?;
    let [Stmt::For { start: st_j, end: end_j, body: bj, .. }] = bi.as_slice() else { return None };
    if !matches!(st_j, Expr::IntLit(1)) {
        return None;
    }
    let m = minus_one_var(end_j)?;
    let [Stmt::Assign { target: LValue::Index { base: dst, .. }, op: AssignOp::Set, value }] =
        bj.as_slice()
    else {
        return None;
    };
    // rhs must reference exactly one other array (src), 4 times
    let mut vars = Vec::new();
    value.collect_vars(&mut vars);
    let mut arrays: Vec<String> = Vec::new();
    collect_index_bases(value, &mut arrays);
    if arrays.len() != 4 {
        return None;
    }
    let src = arrays[0].clone();
    if arrays.iter().any(|a| *a != src) || &src == dst {
        return None;
    }
    Some(vec![src, dst.clone(), n, m])
}

fn collect_index_bases(e: &Expr, out: &mut Vec<String>) {
    match e {
        Expr::Index { base, indices } => {
            out.push(base.clone());
            for i in indices {
                collect_index_bases(i, out);
            }
        }
        Expr::Binary { lhs, rhs, .. } => {
            collect_index_bases(lhs, out);
            collect_index_bases(rhs, out);
        }
        Expr::Unary { operand, .. } => collect_index_bases(operand, out),
        Expr::Intrinsic { args, .. } | Expr::Call { args, .. } => {
            for a in args {
                collect_index_bases(a, out);
            }
        }
        _ => {}
    }
}

fn var_name(e: &Expr) -> Option<String> {
    match e {
        Expr::Var(v) => Some(v.clone()),
        _ => None,
    }
}

/// `n - 1` → Some("n")
fn minus_one_var(e: &Expr) -> Option<String> {
    match e {
        Expr::Binary { op: BinOp::Sub, lhs, rhs } => {
            if matches!(**rhs, Expr::IntLit(1)) {
                var_name(lhs)
            } else {
                None
            }
        }
        _ => None,
    }
}

fn index_is(idx: &[Expr], v1: &str, v2: &str) -> bool {
    matches!(idx, [Expr::Var(a), Expr::Var(b)] if a == v1 && b == v2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::device::CostModel;
    use crate::frontend::parse;
    use crate::measure::Measurer;
    use crate::vm::VmConfig;

    const HANDWRITTEN_MM: &str = r#"
        void main() {
            int n = 32;
            double a[n][n]; double b[n][n]; double c[n][n];
            seed_fill(a, 1);
            seed_fill(b, 2);
            for (int i = 0; i < n; i++) {
                for (int j = 0; j < n; j++) {
                    double s = 0.0;
                    for (int k = 0; k < n; k++) {
                        s += a[i][k] * b[k][j];
                    }
                    c[i][j] = s;
                }
            }
            printf("%f\n", c[5][7]);
        }
    "#;

    #[test]
    fn matmul_extraction_binds_interface() {
        let p = parse(HANDWRITTEN_MM, Lang::C, "t").unwrap();
        let nest = p.find_for(0).unwrap();
        let args = extract_matmul(nest).expect("should extract");
        assert_eq!(args, vec!["a", "b", "c", "n"]);
    }

    #[test]
    fn matmul_extraction_rejects_non_matmul() {
        let src = "void main() { int n = 8; double x[n]; for (int i = 0; i < n; i++) { x[i] = i; } }";
        let p = parse(src, Lang::C, "t").unwrap();
        assert!(extract_matmul(p.find_for(0).unwrap()).is_none());
    }

    #[test]
    fn jacobi_extraction() {
        let src = r#"void main() {
            int n = 16; int m = 16;
            double a[n][m]; double b[n][m];
            for (int i = 1; i < n - 1; i++) {
                for (int j = 1; j < m - 1; j++) {
                    b[i][j] = 0.25 * (a[i - 1][j] + a[i + 1][j] + a[i][j - 1] + a[i][j + 1]);
                }
            }
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let args = extract_jacobi(p.find_for(0).unwrap()).expect("extract");
        assert_eq!(args, vec!["a", "b", "n", "m"]);
    }

    #[test]
    fn clone_candidate_found_for_handwritten_matmul() {
        let p = parse(HANDWRITTEN_MM, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let db = PatternDb::builtin();
        let cands = find_candidates(&p, &a, &db, &FuncBlockConfig::default());
        let clone = cands
            .iter()
            .find(|c| matches!(c.kind, CandidateKind::CloneNest { .. }))
            .expect("clone candidate");
        match &clone.kind {
            CandidateKind::CloneNest { root, kernel, args, score } => {
                assert_eq!(*root, 0);
                assert_eq!(kernel, "matmul");
                assert_eq!(args, &vec!["a".to_string(), "b".into(), "c".into(), "n".into()]);
                assert!(*score > 0.95);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn interface_declined_skips_clone() {
        let p = parse(HANDWRITTEN_MM, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let db = PatternDb::builtin();
        let cfg = FuncBlockConfig { auto_approve_interface: false, ..Default::default() };
        let cands = find_candidates(&p, &a, &db, &cfg);
        assert!(cands.iter().all(|c| !matches!(c.kind, CandidateKind::CloneNest { .. })));
    }

    #[test]
    fn name_match_candidates_for_library_calls() {
        let src = r#"void main() {
            int n = 64;
            double re[n]; double im[n]; double ro[n]; double io[n];
            seed_fill(re, 5);
            dft(re, im, ro, io, n);
            printf("%f\n", ro[3]);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let cands = find_candidates(&p, &a, &PatternDb::builtin(), &FuncBlockConfig::default());
        assert!(cands
            .iter()
            .any(|c| matches!(&c.kind, CandidateKind::NameMatch { lib } if lib == "dft")));
        // seed_fill must NOT be a candidate
        assert!(!cands
            .iter()
            .any(|c| matches!(&c.kind, CandidateKind::NameMatch { lib } if lib == "seed_fill")));
    }

    fn trial_engine<'a>(
        prog: &'a Program,
        measurer: &'a crate::measure::Measurer,
        plan: &'a (dyn Fn(&[bool]) -> ExecPlan + Sync),
        workers: usize,
        factory: crate::device::MultiDeviceFactory,
        dev: &'a mut crate::device::MultiDevice,
    ) -> MeasurementEngine<'a> {
        let cfg = crate::config::Config::fast_sim();
        let fp = crate::engine::fingerprint(prog, &cfg, "funcblock", &[]);
        MeasurementEngine::new(
            prog,
            measurer,
            factory,
            plan,
            workers,
            crate::device::TargetKind::Gpu,
            fp,
            crate::engine::shared(crate::engine::MeasurementCache::in_memory()),
            dev,
            0.0,
        )
    }

    fn gpu_factory() -> crate::device::MultiDeviceFactory {
        crate::device::MultiDeviceFactory::single(CostModel::default(), false)
    }

    #[test]
    fn combination_trial_picks_fastest_and_stays_correct() {
        let p = parse(HANDWRITTEN_MM, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let db = PatternDb::builtin();
        let cfg = FuncBlockConfig::default();
        let cands = find_candidates(&p, &a, &db, &cfg);
        assert!(!cands.is_empty());
        let measurer = Measurer::new(&p, VmConfig::default(), 2e-3).unwrap();
        let set = DeviceSet::single(crate::device::TargetKind::Gpu);
        let plan = mask_plan(&a, &cands, &set, false);
        let mut dev = gpu_factory().build();
        let mut engine = trial_engine(&p, &measurer, &plan, 2, gpu_factory(), &mut dev);
        let report = trial_combinations(&cands, &set, &mut engine, &cfg);
        assert!(report.best.ok);
        // replacing the handwritten nest must beat the interpreted CPU time
        assert!(
            report.best.modeled_s < measurer.baseline_modeled_s(),
            "{} !< {}",
            report.best.modeled_s,
            measurer.baseline_modeled_s()
        );
        assert!(!report.chosen.is_empty(), "GPU replacement should win");
        assert_eq!(report.chosen.len(), report.dests.len());
        assert!(report.dests.iter().all(|&t| t == crate::device::TargetKind::Gpu));
        assert_eq!(report.trials.len(), 1 << cands.len().min(16).min(6));
    }

    #[test]
    fn combination_trial_identical_across_worker_counts() {
        let p = parse(HANDWRITTEN_MM, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let cfg = FuncBlockConfig::default();
        let cands = find_candidates(&p, &a, &PatternDb::builtin(), &cfg);
        let measurer = Measurer::new(&p, VmConfig::default(), 2e-3).unwrap();
        let set = DeviceSet::single(crate::device::TargetKind::Gpu);
        let plan = mask_plan(&a, &cands, &set, false);
        let mut d1 = gpu_factory().build();
        let mut e1 = trial_engine(&p, &measurer, &plan, 1, gpu_factory(), &mut d1);
        let r1 = trial_combinations(&cands, &set, &mut e1, &cfg);
        let mut d4 = gpu_factory().build();
        let mut e4 = trial_engine(&p, &measurer, &plan, 4, gpu_factory(), &mut d4);
        let r4 = trial_combinations(&cands, &set, &mut e4, &cfg);
        assert_eq!(r1.chosen, r4.chosen);
        assert_eq!(r1.dests, r4.dests);
        assert_eq!(r1.trials, r4.trials);
        assert_eq!(r1.best.modeled_s, r4.best.modeled_s);
    }

    #[test]
    fn truncated_trial_budget_still_covers_every_candidate() {
        // untruncated: the plain sequential enumeration (legacy order)
        assert_eq!(trial_assignments(3, 2, 64), (0..8).collect::<Vec<u64>>());
        // truncated multi-device space (3^6 = 729 ≫ 64): every candidate
        // must still be tried alone on every destination
        let combos = trial_assignments(6, 3, 64);
        assert_eq!(combos.len(), 64);
        assert_eq!(combos[0], 0, "CPU-only assignment always first");
        let mut sorted = combos.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), combos.len(), "no duplicate trials");
        for i in 0..6u32 {
            for v in 1..3u64 {
                let single = v * 3u64.pow(i);
                assert!(
                    combos.contains(&single),
                    "candidate {i} on destination {v} never tried"
                );
            }
        }
    }

    #[test]
    fn heterogeneous_trial_enumerates_every_destination() {
        // one candidate × a two-destination set: the trial space is
        // {off, dev0, dev1} — three assignments, best one re-verified
        let src = r#"void main() {
            int n = 64;
            double re[n]; double im[n]; double ro[n]; double io[n];
            seed_fill(re, 5);
            dft(re, im, ro, io, n);
            printf("%f\n", ro[3]);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let cfg = FuncBlockConfig::default();
        let cands = find_candidates(&p, &a, &PatternDb::builtin(), &cfg);
        assert_eq!(cands.len(), 1, "{cands:?}");
        let set = DeviceSet::new(vec![
            crate::device::TargetKind::Gpu,
            crate::device::TargetKind::Fpga,
        ])
        .unwrap();
        let factory = crate::device::MultiDeviceFactory::for_targets(set.devices(), false);
        let measurer = Measurer::new(&p, VmConfig::default(), 2e-3).unwrap();
        let plan = mask_plan(&a, &cands, &set, false);
        let mut dev = factory.build();
        let mut engine = trial_engine(&p, &measurer, &plan, 2, factory, &mut dev);
        let report = trial_combinations(&cands, &set, &mut engine, &cfg);
        assert_eq!(report.trials.len(), 3, "off / gpu / fpga");
        assert!(report.best.ok);
        // all three scores are distinct: the destinations have different
        // cost models, and "off" is the CPU time
        let mut scores: Vec<f64> = report.trials.iter().map(|&(_, t)| t).collect();
        scores.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!(scores.windows(2).all(|w| w[0] < w[1]), "{scores:?}");
    }

    #[test]
    fn swallowed_loops_cover_nest() {
        let p = parse(HANDWRITTEN_MM, Lang::C, "t").unwrap();
        let a = analysis::analyze(&p);
        let c = Candidate {
            kind: CandidateKind::CloneNest {
                root: 0,
                kernel: "matmul".into(),
                args: vec![],
                score: 1.0,
            },
            description: String::new(),
        };
        let swallowed = c.swallowed_loops(&a);
        assert_eq!(swallowed.len(), 3); // i, j, k
    }
}
