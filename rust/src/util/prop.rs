//! Mini property-based tester (proptest is not vendored offline).
//!
//! Strategy: generate `cases` random inputs from a user generator, run the
//! property, and on failure *shrink* by re-generating with smaller size
//! hints, reporting the smallest failing case found. Deterministic per seed
//! so CI failures reproduce.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// maximum "size" hint passed to generators (e.g. max array length)
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, seed: 0xDEC0DE, max_size: 64 }
    }
}

/// Run `prop` on `cases` values from `gen`. `gen` receives (rng, size).
/// Size ramps up from 1 to `max_size` over the run, proptest-style.
/// On failure, tries up to 200 shrink attempts at decreasing sizes and
/// panics with the smallest failing input's Debug rendering.
pub fn check<T: std::fmt::Debug>(
    cfg: &Config,
    mut gen: impl FnMut(&mut Rng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // Shrink: retry with smaller sizes, keep smallest failure.
            let mut smallest_repr = format!("{input:?}");
            let mut smallest_size = size;
            for attempt in 0..200 {
                let s = 1 + attempt % smallest_size.max(1);
                if s >= smallest_size {
                    continue;
                }
                let candidate = gen(&mut rng, s);
                if !prop(&candidate) {
                    smallest_size = s;
                    smallest_repr = format!("{candidate:?}");
                    if s == 1 {
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {:#x});\n  smallest failing input (size {smallest_size}): {smallest_repr}",
                cfg.seed
            );
        }
    }
}

/// Generate a random f64 vector with entries in [-scale, scale].
pub fn vec_f64(rng: &mut Rng, len: usize, scale: f64) -> Vec<f64> {
    (0..len).map(|_| (rng.f64() * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            &Config { cases: 50, ..Default::default() },
            |rng, size| vec_f64(rng, size, 10.0),
            |v| v.iter().all(|x| x.abs() <= 10.0),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrink() {
        check(
            &Config { cases: 50, ..Default::default() },
            |rng, size| vec_f64(rng, size, 1.0),
            |v| v.len() < 3, // fails once size ramps past 2
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let mut outs1 = vec![];
        check(
            &Config { cases: 10, seed: 77, max_size: 8 },
            |rng, size| vec_f64(rng, size, 1.0),
            |v| {
                outs1.push(v.clone());
                true
            },
        );
        let mut outs2 = vec![];
        check(
            &Config { cases: 10, seed: 77, max_size: 8 },
            |rng, size| vec_f64(rng, size, 1.0),
            |v| {
                outs2.push(v.clone());
                true
            },
        );
        assert_eq!(outs1, outs2);
    }
}
