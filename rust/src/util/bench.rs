//! Mini-criterion: warmup + timed iterations + summary statistics.
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Output format is one line per benchmark:
//! `name  mean ± stddev  [min .. max]  (n iters)` plus optional CSV rows
//! for EXPERIMENTS.md tables.

use super::stats::Summary;
use std::time::Instant;

/// One benchmark runner with fixed warmup/measure counts.
pub struct Bench {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    results: Vec<(String, Summary)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 2, measure_iters: 10, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, measure_iters: usize) -> Bench {
        Bench { warmup_iters, measure_iters, results: Vec::new() }
    }

    /// Time `f` (which should perform one complete operation) and record
    /// the summary under `name`. Returns the summary.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::of(&samples);
        println!(
            "{:<48} {:>10} ± {:>8}  [{} .. {}]  ({} iters)",
            name,
            fmt_time(s.mean),
            fmt_time(s.stddev),
            fmt_time(s.min),
            fmt_time(s.max),
            s.n
        );
        self.results.push((name.to_string(), s.clone()));
        s
    }

    pub fn results(&self) -> &[(String, Summary)] {
        &self.results
    }
}

/// Human-readable duration (seconds input).
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Render a Markdown table (used by bench binaries to emit
/// EXPERIMENTS.md-ready blocks).
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str("| ");
    s.push_str(&headers.join(" | "));
    s.push_str(" |\n|");
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for row in rows {
        s.push_str("| ");
        s.push_str(&row.join(" | "));
        s.push_str(" |\n");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_records_result() {
        let mut b = Bench::new(1, 3);
        let s = b.run("noop", || 1 + 1);
        assert_eq!(s.n, 3);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].0, "noop");
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(0.0025), "2.500ms");
        assert_eq!(fmt_time(2.5e-6), "2.500µs");
        assert_eq!(fmt_time(2.5e-9), "2.5ns");
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(t, "| a | b |\n|---|---|\n| 1 | 2 |\n");
    }
}
