//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate
//! vendored, so the usual ecosystem crates (rand, serde_json, criterion,
//! proptest, clap) are unavailable. This module provides the small,
//! well-tested subset of each that the rest of the crate needs:
//!
//! - [`rng`]  — xoshiro256** PRNG (GA, property tests, workload data)
//! - [`stats`] — mean / stddev / percentiles for measurements
//! - [`json`] — minimal JSON *writer* for reports and bench output
//! - [`bench`] — mini-criterion: warmup + timed iterations + stats
//! - [`prop`] — mini-proptest: randomized property checks with shrinking

pub mod bench;
pub mod fxhash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
