//! Minimal JSON writer + reader (no serde available offline).
//!
//! The writer covers what reports and bench output need: objects, arrays,
//! strings, numbers, bools; control characters and quotes are escaped
//! correctly. The reader ([`Json::parse`]) is the inverse, added for the
//! offload service's line-delimited JSON protocol (`proto`, `server`): a
//! strict recursive-descent parser over the same value model, plus the
//! field accessors (`get`, `as_str`, ...) request handlers need.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or append) a key into an object. Panics on non-objects.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kvs) => kvs.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// First value stored under `key` (objects only).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric value: `Num` directly, `Int` widened.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Parse one JSON value from `text` (the whole string must be consumed,
    /// modulo surrounding whitespace). Errors carry a byte offset.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { s: text, b: text.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing characters at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    // JSON has no Inf/NaN; encode as null (report-friendly).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !xs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !kvs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

/// Containers deeper than this are rejected — recursion must stay
/// bounded, or one deeply nested line could overflow the stack of
/// whatever thread parses untrusted input (the serve daemon's).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    /// the input as a str (for O(1) decoding of multi-byte characters —
    /// `i` only ever rests on a character boundary)
    s: &'a str,
    b: &'a [u8],
    i: usize,
    /// current container nesting depth
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected `{}` at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.i));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, String> {
        self.enter()?;
        let r = self.object_body();
        self.depth -= 1;
        r
    }

    fn array(&mut self) -> Result<Json, String> {
        self.enter()?;
        let r = self.array_body();
        self.depth -= 1;
        r
    }

    fn object_body(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            kvs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("expected `,` or `}}` at byte {}", self.i)),
            }
        }
    }

    fn array_body(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(format!("expected `,` or `]` at byte {}", self.i)),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        // `get` also rejects a slice ending inside a multi-byte character
        let digits = self
            .s
            .get(self.i..self.i + 4)
            .ok_or_else(|| format!("bad \\u escape at byte {}", self.i))?;
        let v = u32::from_str_radix(digits, 16)
            .map_err(|_| format!("bad \\u escape at byte {}", self.i))?;
        self.i += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.i += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.i += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.i += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.i += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.i += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.i += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{8}');
                            self.i += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{c}');
                            self.i += 1;
                        }
                        Some(b'u') => {
                            self.i += 1;
                            let mut code = self.hex4()?;
                            // surrogate pair: combine with a following \uXXXX
                            if (0xD800..0xDC00).contains(&code)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                let save = self.i;
                                self.i += 2;
                                let lo = self.hex4()?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    code = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                } else {
                                    self.i = save;
                                }
                            }
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar — O(1): `i` is always on a
                    // character boundary, so the str slice decodes the
                    // next char without rescanning the remaining input
                    let start = self.i;
                    let c = self.s[start..].chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(format!("raw control character at byte {start}"));
                    }
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let tok = &self.s[start..self.i]; // ASCII-only span: boundaries hold
        if !float {
            if let Ok(i) = tok.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{tok}` at byte {start}"))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_nested_object() {
        let j = Json::obj()
            .set("name", "envadapt")
            .set("n", 3usize)
            .set("ok", true)
            .set("xs", Json::Arr(vec![Json::Int(1), Json::Int(2)]));
        assert_eq!(
            j.to_string(),
            r#"{"name":"envadapt","n":3,"ok":true,"xs":[1,2]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_prints() {
        let j = Json::obj().set("a", 1i64);
        assert_eq!(j.to_pretty(), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = Json::obj()
            .set("name", "envadapt")
            .set("n", 3usize)
            .set("x", 1.25f64)
            .set("neg", -7i64)
            .set("ok", true)
            .set("none", Json::Null)
            .set("code", "line1\nline2\t\"quoted\"\\")
            .set("xs", Json::Arr(vec![Json::Int(1), Json::Int(2), Json::Str("a".into())]));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
        // and the pretty form parses to the same value
        assert_eq!(Json::parse(&j.to_pretty()).unwrap(), j);
    }

    #[test]
    fn parse_accessors() {
        let j = Json::parse(r#"{"op":"offload","id":42,"f":2.5,"on":true,"xs":[1,2]}"#).unwrap();
        assert_eq!(j.get("op").and_then(|v| v.as_str()), Some("offload"));
        assert_eq!(j.get("id").and_then(|v| v.as_i64()), Some(42));
        assert_eq!(j.get("id").and_then(|v| v.as_f64()), Some(42.0));
        assert_eq!(j.get("f").and_then(|v| v.as_f64()), Some(2.5));
        assert_eq!(j.get("on").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(j.get("xs").and_then(|v| v.items()).map(|x| x.len()), Some(2));
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parse_unicode_escapes() {
        // raw multi-byte characters pass through
        let j = Json::parse(r#""aAé😀b""#).unwrap();
        assert_eq!(j.as_str(), Some("aAé😀b"));
        // \uXXXX escapes, including a surrogate pair
        let j = Json::parse("\"a\\u00e9\\ud83d\\ude00b\"").unwrap();
        assert_eq!(j.as_str(), Some("aé😀b"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,2,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn parse_bounds_nesting_depth() {
        // pathological nesting must be an error, not a stack overflow
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        let balanced = format!("{}1{}", "[".repeat(5_000), "]".repeat(5_000));
        assert!(Json::parse(&balanced).is_err());
        // reasonable nesting still parses
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("0").unwrap(), Json::Int(0));
        assert_eq!(Json::parse("-12").unwrap(), Json::Int(-12));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("2.5e-1").unwrap(), Json::Num(0.25));
        // integers beyond i64 fall back to f64
        assert!(matches!(Json::parse("99999999999999999999").unwrap(), Json::Num(_)));
    }
}
