//! Minimal JSON writer (no serde available offline).
//!
//! Only what reports and bench output need: objects, arrays, strings,
//! numbers, bools. Escapes control characters and quotes correctly.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or append) a key into an object. Panics on non-objects.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(kvs) => kvs.push((key.to_string(), val.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    // JSON has no Inf/NaN; encode as null (report-friendly).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !xs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !kvs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_nested_object() {
        let j = Json::obj()
            .set("name", "envadapt")
            .set("n", 3usize)
            .set("ok", true)
            .set("xs", Json::Arr(vec![Json::Int(1), Json::Int(2)]));
        assert_eq!(
            j.to_string(),
            r#"{"name":"envadapt","n":3,"ok":true,"xs":[1,2]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(j.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn pretty_prints() {
        let j = Json::obj().set("a", 1i64);
        assert_eq!(j.to_pretty(), "{\n  \"a\": 1\n}");
    }
}
