//! Deterministic PRNG: xoshiro256** (Blackman & Vigna).
//!
//! Used by the GA, the property tester and workload data generation.
//! Deterministic seeding keeps every experiment in EXPERIMENTS.md exactly
//! reproducible.

/// xoshiro256** state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random boolean.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Derive an independent child stream (for per-thread RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_of_f64_near_half() {
        let mut r = Rng::new(1234);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(3);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..100).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
