//! FxHash (rustc's hasher): a fast non-cryptographic hash for the VM's
//! hot-path maps. ~2× faster than SipHash for short string keys; DoS
//! resistance is irrelevant for interpreter environments.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut word = 0u64;
            for (i, &b) in rem.iter().enumerate() {
                word |= (b as u64) << (8 * i);
            }
            self.add(word);
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_with_string_keys() {
        let mut m: FxHashMap<String, i32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(format!("var_{i}"), i);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000 {
            assert_eq!(m.get(&format!("var_{i}")), Some(&i));
        }
    }

    #[test]
    fn deterministic() {
        let mut h1 = FxHasher::default();
        let mut h2 = FxHasher::default();
        h1.write(b"hello world, envadapt");
        h2.write(b"hello world, envadapt");
        assert_eq!(h1.finish(), h2.finish());
    }

    #[test]
    fn different_inputs_differ() {
        let mut h1 = FxHasher::default();
        let mut h2 = FxHasher::default();
        h1.write(b"aaa");
        h2.write(b"aab");
        assert_ne!(h1.finish(), h2.finish());
    }
}
