//! Summary statistics over measurement samples.

/// Summary of a sample set (times in seconds, or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Geometric mean (for speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
        assert!((geomean(&[5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }
}
