//! Register-bytecode engine for the measurement hot path.
//!
//! The GA evaluates thousands of candidate genes against one program; the
//! tree-walking interpreter in [`crate::vm`] re-walks the IR and re-hashes
//! string-keyed environments for every one of them. This module compiles a
//! [`Program`] **once** into a flat register bytecode — locals resolved to
//! frame slots, loop bounds constant-folded, statement charges batched —
//! and executes it with a tight dispatch loop. The [`crate::vm::ExecPlan`]
//! (the placement gene's rendering) is consulted only at region-boundary
//! ops, so one compiled artifact serves every gene evaluation.
//!
//! The contract is **bit-identical semantics** with the tree-walker: the
//! same [`Outcome`] (prints, `cpu_ops`, `gpu_ops`, seconds, energy,
//! transfers, residency staging) for every program/plan pair on which both
//! engines succeed, and failure on the same program/plan pairs (error
//! *messages* and partially-accumulated state may differ on the failure
//! path — outcomes of failed runs are discarded by the measurement layer).
//! `tests/bytecode_differential.rs` and `tests/property.rs` prove the
//! contract differentially; the tree-walker remains the semantic reference
//! and stays reachable via [`crate::vm::ExecEngine::TreeWalk`].
//!
//! Programs that exceed the compiler's nesting or register budgets fail to
//! compile; callers (see [`crate::measure::Measurer`]) fall back to the
//! reference interpreter, so pathological inputs lose speed, never
//! correctness.

use crate::ir::*;
use crate::libs;
use crate::util::fxhash::FxHashMap;
use crate::vm::{
    self, new_array, ArrayRef, Device, ExecPlan, GpuRegion, NullDevice, Outcome, RegionExec,
    Value, VmConfig,
};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::Ordering;

/// Compiler recursion guard. The front ends already bound nesting at
/// `MAX_PARSE_DEPTH` (160); this slightly larger bound exists for
/// programmatically built IR, so deep trees fail with a clean error
/// instead of overflowing the compiler's stack.
pub const MAX_COMPILE_DEPTH: usize = 200;

/// Per-function frame-register ceiling — bounds register allocation on
/// adversarial inputs (compile error → reference-interpreter fallback).
pub const MAX_FRAME_REGS: usize = 1 << 16;

type Reg = u32;

/// A counted-loop bound: folded literal or register (satellite bugfix —
/// literal bounds never touch the environment at run time).
#[derive(Debug, Clone, Copy)]
enum Bound {
    Const(i64),
    Reg(Reg),
}

/// One bytecode instruction. Register operands index the frame; jump
/// targets are absolute instruction indices within the function.
#[derive(Debug, Clone)]
enum Instr {
    /// batched op-count charge (sum of per-node charges since the last
    /// flush point; flushed before every label and control transfer so
    /// both engines agree on totals at every observable point)
    Charge(u64),
    /// bump the `VmConfig` bound-eval test counter by `n` (number of
    /// loop bounds at this site that still need dynamic evaluation)
    BoundEvals(u64),
    LoadInt { dst: Reg, v: i64 },
    LoadFloat { dst: Reg, v: f64 },
    /// `dst = as_i64(src)` — `int` declaration coercion
    CastInt { dst: Reg, src: Reg },
    Copy { dst: Reg, src: Reg },
    Bin { op: BinOp, dst: Reg, a: Reg, b: Reg },
    Neg { dst: Reg, src: Reg },
    Not { dst: Reg, src: Reg },
    /// `dst = Int(truthy(src))` — the joining write of `&&` / `||`
    Truthy { dst: Reg, src: Reg },
    Intr { f: Intrinsic, dst: Reg, a: Reg, b: Reg },
    Len { dst: Reg, base: Reg, dim: usize },
    LoadIdx { dst: Reg, base: Reg, idx: Box<[Reg]> },
    StoreIdx { base: Reg, idx: Box<[Reg]>, op: AssignOp, src: Reg },
    AllocArr { dst: Reg, dims: Box<[Reg]> },
    Print { src: Reg },
    Jump(u32),
    JumpIfFalsy { cond: Reg, to: u32 },
    JumpIfTruthy { cond: Reg, to: u32 },
    /// call in statement (`dst: None`) or expression position; `user` is a
    /// pre-resolved function index, `is_lib` a pre-resolved library-name
    /// check — the plan's `gpu_calls` routing stays a run-time decision
    Call { name: Box<str>, user: Option<u32>, is_lib: bool, args: Box<[Reg]>, dst: Option<Reg> },
    /// region-boundary marker at a `for` statement: consults the plan; a
    /// `Library` region executes entirely here and jumps to `after`
    RegionEnter { id: LoopId, after: u32 },
    /// counted-loop entry: resolve bounds, record region parallelism,
    /// save the loop variable, bind it, or jump to `exit` when zero-trip
    LoopInit {
        site: u32,
        id: LoopId,
        var: Reg,
        save: Reg,
        start: Bound,
        end: Bound,
        step: Bound,
        exit: u32,
    },
    /// counted-loop back edge: advance, re-check, re-bind
    LoopNext { site: u32, var: Reg, body: u32 },
    /// restore the loop variable's pre-loop binding
    LoopRestore { var: Reg, save: Reg },
    /// region-boundary marker at loop exit: flush generic-kernel charges
    RegionExit { id: LoopId },
    /// explicit `return` (checked against active-region escape)
    Ret { src: Option<Reg> },
    /// implicit fall-off end of a function body
    End,
    /// compile-time-known run-time error (e.g. `break` outside any loop)
    Fail(Box<str>),
}

/// Per-frame state of one `for` site. A site is re-initialized by
/// `LoopInit` on every entry, and a frame never runs the same site
/// concurrently with itself, so one state per site suffices.
#[derive(Debug, Clone, Copy)]
struct LoopState {
    i: i64,
    end: i64,
    step: i64,
}

#[derive(Debug)]
struct CompiledFunc {
    name: String,
    n_params: usize,
    /// names of the named slots (`slot_names[r]` labels register `r` for
    /// error messages; temps sit above and have no names)
    slot_names: Vec<String>,
    /// name → named-slot register, for plan-supplied names (region copy
    /// lists, library-region args)
    slots: FxHashMap<String, Reg>,
    /// total frame registers: named slots, then one save register per
    /// `for` site, then statement temporaries
    frame: usize,
    /// number of `for` sites (extent of the frame's loop-state array)
    sites: usize,
    code: Vec<Instr>,
}

/// A program compiled to register bytecode. Plain data (`Send + Sync`):
/// the measurement pool shares one artifact across worker threads via
/// `Arc` — see `crate::engine::CompiledCache`.
#[derive(Debug)]
pub struct CompiledProgram {
    funcs: Vec<CompiledFunc>,
    entry: usize,
}

impl CompiledProgram {
    /// Total instruction count across all functions (diagnostics/tests).
    pub fn instr_count(&self) -> usize {
        self.funcs.iter().map(|f| f.code.len()).sum()
    }

    /// Number of compiled functions.
    pub fn func_count(&self) -> usize {
        self.funcs.len()
    }
}

// Shared across the measurement pool by Arc; must stay plain data.
#[allow(dead_code)]
fn _compiled_is_shareable() {
    fn send_sync<T: Send + Sync>() {}
    send_sync::<CompiledProgram>();
}

// ---------------------------------------------------------------------------
// compiler
// ---------------------------------------------------------------------------

/// Compile `prog` to bytecode. Fails (cleanly) on IR that exceeds the
/// nesting or register budgets, on intrinsic arity mismatches, or when no
/// `main` exists — callers fall back to the reference interpreter.
pub fn compile(prog: &Program) -> Result<CompiledProgram> {
    let entry = prog
        .functions
        .iter()
        .position(|f| f.name == "main")
        .ok_or_else(|| anyhow!("program has no `main` function"))?;
    let funcs = prog
        .functions
        .iter()
        .map(|f| compile_func(prog, f))
        .collect::<Result<Vec<_>>>()?;
    Ok(CompiledProgram { funcs, entry })
}

/// Ordered name → slot assignment for one function.
#[derive(Default)]
struct NameSet {
    names: Vec<String>,
    index: FxHashMap<String, Reg>,
}

impl NameSet {
    fn add(&mut self, n: &str) -> Result<()> {
        if !self.index.contains_key(n) {
            if self.names.len() >= MAX_FRAME_REGS {
                bail!("function uses too many variables");
            }
            self.index.insert(n.to_string(), self.names.len() as Reg);
            self.names.push(n.to_string());
        }
        Ok(())
    }
}

fn scan_stmt(s: &Stmt, ns: &mut NameSet, sites: &mut usize, d: usize) -> Result<()> {
    if d > MAX_COMPILE_DEPTH {
        bail!("program nests too deeply to compile (depth > {MAX_COMPILE_DEPTH})");
    }
    match s {
        Stmt::Decl { name, dims, init, .. } => {
            ns.add(name)?;
            for e in dims {
                scan_expr(e, ns, d + 1)?;
            }
            if let Some(e) = init {
                scan_expr(e, ns, d + 1)?;
            }
        }
        Stmt::Assign { target, value, .. } => {
            ns.add(target.base_name())?;
            if let LValue::Index { indices, .. } = target {
                for e in indices {
                    scan_expr(e, ns, d + 1)?;
                }
            }
            scan_expr(value, ns, d + 1)?;
        }
        Stmt::For { var, start, end, step, body, .. } => {
            *sites += 1;
            ns.add(var)?;
            scan_expr(start, ns, d + 1)?;
            scan_expr(end, ns, d + 1)?;
            scan_expr(step, ns, d + 1)?;
            for s in body {
                scan_stmt(s, ns, sites, d + 1)?;
            }
        }
        Stmt::While { cond, body } => {
            scan_expr(cond, ns, d + 1)?;
            for s in body {
                scan_stmt(s, ns, sites, d + 1)?;
            }
        }
        Stmt::If { cond, then_body, else_body } => {
            scan_expr(cond, ns, d + 1)?;
            for s in then_body.iter().chain(else_body) {
                scan_stmt(s, ns, sites, d + 1)?;
            }
        }
        Stmt::Call { args, .. } => {
            for e in args {
                scan_expr(e, ns, d + 1)?;
            }
        }
        Stmt::Return(Some(e)) | Stmt::Print(e) => scan_expr(e, ns, d + 1)?,
        Stmt::Return(None) | Stmt::Break | Stmt::Continue => {}
    }
    Ok(())
}

fn scan_expr(e: &Expr, ns: &mut NameSet, d: usize) -> Result<()> {
    if d > MAX_COMPILE_DEPTH {
        bail!("expression nests too deeply to compile (depth > {MAX_COMPILE_DEPTH})");
    }
    match e {
        Expr::IntLit(_) | Expr::FloatLit(_) => {}
        Expr::Var(n) => ns.add(n)?,
        Expr::Index { base, indices } => {
            ns.add(base)?;
            for i in indices {
                scan_expr(i, ns, d + 1)?;
            }
        }
        Expr::Binary { lhs, rhs, .. } => {
            scan_expr(lhs, ns, d + 1)?;
            scan_expr(rhs, ns, d + 1)?;
        }
        Expr::Unary { operand, .. } => scan_expr(operand, ns, d + 1)?,
        Expr::Intrinsic { args, .. } | Expr::Call { args, .. } => {
            for a in args {
                scan_expr(a, ns, d + 1)?;
            }
        }
        Expr::Len { base, .. } => ns.add(base)?,
    }
    Ok(())
}

/// Break/continue patch lists of one enclosing loop.
#[derive(Default)]
struct LoopCtx {
    breaks: Vec<usize>,
    continues: Vec<usize>,
}

struct FnCompiler<'a> {
    prog: &'a Program,
    fname: &'a str,
    slots: FxHashMap<String, Reg>,
    /// first temp register (named slots + save registers sit below)
    tmp_base: usize,
    tmp_next: usize,
    tmp_max: usize,
    save_base: usize,
    next_site: u32,
    code: Vec<Instr>,
    /// charges accumulated since the last flush point
    pending: u64,
    loops: Vec<LoopCtx>,
}

fn compile_func(prog: &Program, f: &Function) -> Result<CompiledFunc> {
    let mut ns = NameSet::default();
    for p in &f.params {
        ns.add(&p.name)?;
    }
    let mut sites = 0usize;
    for s in &f.body {
        scan_stmt(s, &mut ns, &mut sites, 0)?;
    }
    let n_named = ns.names.len();
    let tmp_base = n_named + sites;
    if tmp_base >= MAX_FRAME_REGS {
        bail!("function frame exceeds the register budget");
    }
    let mut c = FnCompiler {
        prog,
        fname: &f.name,
        slots: ns.index,
        tmp_base,
        tmp_next: tmp_base,
        tmp_max: tmp_base,
        save_base: n_named,
        next_site: 0,
        code: Vec::new(),
        pending: 0,
        loops: Vec::new(),
    };
    for s in &f.body {
        c.stmt(s, 0)?;
    }
    c.flush();
    c.code.push(Instr::End);
    debug_assert_eq!(c.next_site as usize, sites);
    Ok(CompiledFunc {
        name: f.name.clone(),
        n_params: f.params.len(),
        slot_names: ns.names,
        slots: c.slots,
        frame: c.tmp_max,
        sites,
        code: c.code,
    })
}

impl<'a> FnCompiler<'a> {
    fn flush(&mut self) {
        if self.pending > 0 {
            self.code.push(Instr::Charge(self.pending));
            self.pending = 0;
        }
    }

    /// Flush pending charges and return the next instruction index — every
    /// jump target must be created through here so batched charges never
    /// straddle a label.
    fn label(&mut self) -> u32 {
        self.flush();
        self.code.len() as u32
    }

    /// Emit an instruction whose jump target is patched later.
    fn emit_patch(&mut self, i: Instr) -> usize {
        self.code.push(i);
        self.code.len() - 1
    }

    fn patch(&mut self, at: usize, to: u32) {
        match &mut self.code[at] {
            Instr::Jump(t)
            | Instr::JumpIfFalsy { to: t, .. }
            | Instr::JumpIfTruthy { to: t, .. }
            | Instr::RegionEnter { after: t, .. }
            | Instr::LoopInit { exit: t, .. } => *t = to,
            other => unreachable!("patching non-jump instruction {other:?}"),
        }
    }

    fn slot(&self, name: &str) -> Reg {
        // the scan pre-pass registered every name that can appear
        self.slots[name]
    }

    fn tmp(&mut self) -> Result<Reg> {
        let r = self.tmp_next;
        if r >= MAX_FRAME_REGS {
            bail!("expression needs too many registers");
        }
        self.tmp_next += 1;
        self.tmp_max = self.tmp_max.max(self.tmp_next);
        Ok(r as Reg)
    }

    // ---- statements -------------------------------------------------------

    fn stmt(&mut self, s: &Stmt, d: usize) -> Result<()> {
        if d > MAX_COMPILE_DEPTH {
            bail!("program nests too deeply to compile (depth > {MAX_COMPILE_DEPTH})");
        }
        // temporaries never live across statements
        self.tmp_next = self.tmp_base;
        // the tree-walker charges 1 per executed statement
        self.pending += 1;
        match s {
            Stmt::Decl { name, ty, dims, init } => {
                let dst = self.slot(name);
                if dims.is_empty() {
                    match init {
                        Some(e) => {
                            let r = self.expr(e, d + 1)?;
                            match ty {
                                Type::Int => self.code.push(Instr::CastInt { dst, src: r }),
                                _ => self.code.push(Instr::Copy { dst, src: r }),
                            }
                        }
                        None => match ty {
                            Type::Int => self.code.push(Instr::LoadInt { dst, v: 0 }),
                            _ => self.code.push(Instr::LoadFloat { dst, v: 0.0 }),
                        },
                    }
                } else {
                    let mut regs = Vec::with_capacity(dims.len());
                    for e in dims {
                        regs.push(self.expr(e, d + 1)?);
                    }
                    self.code.push(Instr::AllocArr { dst, dims: regs.into_boxed_slice() });
                }
                Ok(())
            }
            Stmt::Assign { target, op, value } => {
                let rhs = self.expr(value, d + 1)?;
                match target {
                    LValue::Var(name) => {
                        let dst = self.slot(name);
                        match op {
                            AssignOp::Set => self.code.push(Instr::Copy { dst, src: rhs }),
                            _ => self.code.push(Instr::Bin {
                                op: compound_binop(*op),
                                dst,
                                a: dst,
                                b: rhs,
                            }),
                        }
                    }
                    LValue::Index { base, indices } => {
                        let mut regs = Vec::with_capacity(indices.len().min(8));
                        for e in indices.iter().take(8) {
                            regs.push(self.expr(e, d + 1)?);
                        }
                        self.code.push(Instr::StoreIdx {
                            base: self.slot(base),
                            idx: regs.into_boxed_slice(),
                            op: *op,
                            src: rhs,
                        });
                    }
                }
                Ok(())
            }
            Stmt::For { .. } => self.for_stmt(s, d),
            Stmt::While { cond, body } => {
                let head = self.label();
                self.pending += 1; // per-iteration loop check
                let c = self.expr(cond, d + 1)?;
                self.flush();
                let jexit = self.emit_patch(Instr::JumpIfFalsy { cond: c, to: u32::MAX });
                self.loops.push(LoopCtx::default());
                for s in body {
                    self.stmt(s, d + 1)?;
                }
                self.flush();
                self.code.push(Instr::Jump(head));
                let ctx = self.loops.pop().unwrap();
                let end = self.label();
                self.patch(jexit, end);
                for j in ctx.breaks {
                    self.patch(j, end);
                }
                for j in ctx.continues {
                    self.patch(j, head);
                }
                Ok(())
            }
            Stmt::If { cond, then_body, else_body } => {
                let c = self.expr(cond, d + 1)?;
                self.flush();
                let jelse = self.emit_patch(Instr::JumpIfFalsy { cond: c, to: u32::MAX });
                for s in then_body {
                    self.stmt(s, d + 1)?;
                }
                if else_body.is_empty() {
                    let end = self.label();
                    self.patch(jelse, end);
                } else {
                    self.flush();
                    let jend = self.emit_patch(Instr::Jump(u32::MAX));
                    let lelse = self.label();
                    self.patch(jelse, lelse);
                    for s in else_body {
                        self.stmt(s, d + 1)?;
                    }
                    let end = self.label();
                    self.patch(jend, end);
                }
                Ok(())
            }
            Stmt::Call { name, args } => {
                let regs = self.arg_regs(args, d)?;
                self.flush();
                self.code.push(self.make_call(name, regs, None));
                Ok(())
            }
            Stmt::Return(e) => {
                let src = match e {
                    Some(e) => Some(self.expr(e, d + 1)?),
                    None => None,
                };
                self.flush();
                self.code.push(Instr::Ret { src });
                Ok(())
            }
            Stmt::Break | Stmt::Continue => {
                let is_break = matches!(s, Stmt::Break);
                self.flush();
                if self.loops.is_empty() {
                    // same run-time error the tree-walker raises when the
                    // flow escapes the function body
                    let msg = if self.fname == "main" {
                        "break/continue escaped function body".to_string()
                    } else {
                        format!("break/continue escaped function `{}`", self.fname)
                    };
                    self.code.push(Instr::Fail(msg.into_boxed_str()));
                } else {
                    let j = self.emit_patch(Instr::Jump(u32::MAX));
                    let ctx = self.loops.last_mut().unwrap();
                    if is_break {
                        ctx.breaks.push(j);
                    } else {
                        ctx.continues.push(j);
                    }
                }
                Ok(())
            }
            Stmt::Print(e) => {
                let r = self.expr(e, d + 1)?;
                self.code.push(Instr::Print { src: r });
                Ok(())
            }
        }
    }

    /// `for` layout:
    ///
    /// ```text
    ///   Charge(..)                  ← statement charge, pre-entry mode
    ///   RegionEnter{id, after}      ← Library regions run here, jump after
    ///   <dynamic bound evals>       ← literals folded into LoopInit
    ///   BoundEvals(n_dynamic)
    ///   LoopInit{.., exit}          ← zero-trip jumps to exit
    /// body:
    ///   <body stmts>                ← break → exit, continue → next
    /// next:
    ///   LoopNext{.., body}
    /// exit:
    ///   LoopRestore
    ///   RegionExit{id}              ← generic-kernel flush + copy-out
    /// after:
    /// ```
    fn for_stmt(&mut self, s: &Stmt, d: usize) -> Result<()> {
        let Stmt::For { id, var, start, end, step, body } = s else { unreachable!() };
        let site = self.next_site;
        self.next_site += 1;
        let save = (self.save_base + site as usize) as Reg;
        let var_slot = self.slot(var);
        self.flush();
        let re = self.emit_patch(Instr::RegionEnter { id: *id, after: u32::MAX });
        let mut dynamic = 0u64;
        let sb = self.bound(start, &mut dynamic, d)?;
        let eb = self.bound(end, &mut dynamic, d)?;
        let pb = self.bound(step, &mut dynamic, d)?;
        if dynamic > 0 {
            self.code.push(Instr::BoundEvals(dynamic));
        }
        self.flush();
        let li = self.emit_patch(Instr::LoopInit {
            site,
            id: *id,
            var: var_slot,
            save,
            start: sb,
            end: eb,
            step: pb,
            exit: u32::MAX,
        });
        let body_head = self.label();
        self.loops.push(LoopCtx::default());
        for s in body {
            self.stmt(s, d + 1)?;
        }
        self.flush();
        let next = self.code.len();
        self.code.push(Instr::LoopNext { site, var: var_slot, body: body_head });
        let ctx = self.loops.pop().unwrap();
        let exit = self.label();
        self.code.push(Instr::LoopRestore { var: var_slot, save });
        self.code.push(Instr::RegionExit { id: *id });
        let after = self.label();
        self.patch(re, after);
        self.patch(li, exit);
        for j in ctx.breaks {
            self.patch(j, exit);
        }
        for j in ctx.continues {
            self.patch(j, next as u32);
        }
        Ok(())
    }

    /// A loop bound: literals fold to a constant (still charged — the
    /// tree-walker pays one op per bound node); everything else evaluates
    /// through the generic path into a register.
    fn bound(&mut self, e: &Expr, dynamic: &mut u64, d: usize) -> Result<Bound> {
        match e {
            Expr::IntLit(v) => {
                self.pending += 1;
                Ok(Bound::Const(*v))
            }
            // same truncating/saturating cast `as_i64` applies at run time
            Expr::FloatLit(v) => {
                self.pending += 1;
                Ok(Bound::Const(*v as i64))
            }
            _ => {
                *dynamic += 1;
                let r = self.expr(e, d + 1)?;
                Ok(Bound::Reg(r))
            }
        }
    }

    fn arg_regs(&mut self, args: &[Expr], d: usize) -> Result<Box<[Reg]>> {
        let mut regs = Vec::with_capacity(args.len());
        for a in args {
            regs.push(self.expr(a, d + 1)?);
        }
        Ok(regs.into_boxed_slice())
    }

    fn make_call(&self, name: &str, args: Box<[Reg]>, dst: Option<Reg>) -> Instr {
        let user = self
            .prog
            .functions
            .iter()
            .position(|f| f.name == name)
            .map(|i| i as u32);
        Instr::Call {
            name: name.to_string().into_boxed_str(),
            user,
            is_lib: libs::is_library(name),
            args,
            dst,
        }
    }

    // ---- expressions ------------------------------------------------------

    /// Compile `e`; returns the register holding its value. Charges one op
    /// per IR node into `pending`, exactly like the tree-walker's `eval`.
    fn expr(&mut self, e: &Expr, d: usize) -> Result<Reg> {
        if d > MAX_COMPILE_DEPTH {
            bail!("expression nests too deeply to compile (depth > {MAX_COMPILE_DEPTH})");
        }
        self.pending += 1;
        match e {
            Expr::IntLit(v) => {
                let t = self.tmp()?;
                self.code.push(Instr::LoadInt { dst: t, v: *v });
                Ok(t)
            }
            Expr::FloatLit(v) => {
                let t = self.tmp()?;
                self.code.push(Instr::LoadFloat { dst: t, v: *v });
                Ok(t)
            }
            Expr::Var(n) => Ok(self.slot(n)),
            Expr::Index { base, indices } => {
                let mut regs = Vec::with_capacity(indices.len().min(8));
                for e in indices.iter().take(8) {
                    regs.push(self.expr(e, d + 1)?);
                }
                let t = self.tmp()?;
                self.code.push(Instr::LoadIdx {
                    dst: t,
                    base: self.slot(base),
                    idx: regs.into_boxed_slice(),
                });
                Ok(t)
            }
            Expr::Binary { op: op @ (BinOp::And | BinOp::Or), lhs, rhs } => {
                // short-circuit: the rhs (and its charges) only run when
                // the lhs doesn't decide — hence the in-branch flush
                let a = self.expr(lhs, d + 1)?;
                let t = self.tmp()?;
                self.flush();
                let jshort = if *op == BinOp::And {
                    self.emit_patch(Instr::JumpIfFalsy { cond: a, to: u32::MAX })
                } else {
                    self.emit_patch(Instr::JumpIfTruthy { cond: a, to: u32::MAX })
                };
                let b = self.expr(rhs, d + 1)?;
                self.flush();
                self.code.push(Instr::Truthy { dst: t, src: b });
                let jend = self.emit_patch(Instr::Jump(u32::MAX));
                let lshort = self.label();
                let v = if *op == BinOp::And { 0 } else { 1 };
                self.code.push(Instr::LoadInt { dst: t, v });
                let end = self.label();
                self.patch(jshort, lshort);
                self.patch(jend, end);
                Ok(t)
            }
            Expr::Binary { op, lhs, rhs } => {
                let a = self.expr(lhs, d + 1)?;
                let b = self.expr(rhs, d + 1)?;
                let t = self.tmp()?;
                self.code.push(Instr::Bin { op: *op, dst: t, a, b });
                Ok(t)
            }
            Expr::Unary { op, operand } => {
                let r = self.expr(operand, d + 1)?;
                let t = self.tmp()?;
                match op {
                    UnOp::Neg => self.code.push(Instr::Neg { dst: t, src: r }),
                    UnOp::Not => self.code.push(Instr::Not { dst: t, src: r }),
                }
                Ok(t)
            }
            Expr::Intrinsic { f, args } => {
                if args.len() < f.arity() {
                    bail!(
                        "intrinsic `{}` needs {} arguments, got {}",
                        f.name(),
                        f.arity(),
                        args.len()
                    );
                }
                // the tree-walker evaluates (and charges) every argument
                let regs = self.arg_regs(args, d)?;
                let a = regs[0];
                let b = if f.arity() == 2 { regs[1] } else { a };
                let t = self.tmp()?;
                self.code.push(Instr::Intr { f: *f, dst: t, a, b });
                Ok(t)
            }
            Expr::Call { name, args } => {
                let regs = self.arg_regs(args, d)?;
                let t = self.tmp()?;
                self.flush();
                self.code.push(self.make_call(name, regs, Some(t)));
                Ok(t)
            }
            Expr::Len { base, dim } => {
                let t = self.tmp()?;
                self.code.push(Instr::Len { dst: t, base: self.slot(base), dim: *dim });
                Ok(t)
            }
        }
    }
}

fn compound_binop(op: AssignOp) -> BinOp {
    match op {
        AssignOp::Add => BinOp::Add,
        AssignOp::Sub => BinOp::Sub,
        AssignOp::Mul => BinOp::Mul,
        AssignOp::Div => BinOp::Div,
        AssignOp::Set => unreachable!("plain assignment compiles to Copy"),
    }
}

// ---------------------------------------------------------------------------
// executor
// ---------------------------------------------------------------------------

/// The generic-kernel region currently being interpreted.
#[derive(Debug)]
struct ActiveRegion {
    region: GpuRegion,
    /// call depth at entry: a `return` unwinding this frame escapes
    depth: usize,
}

struct Exec<'a> {
    prog: &'a CompiledProgram,
    plan: &'a ExecPlan,
    dev: &'a mut dyn Device,
    cfg: VmConfig,
    cpu_ops: u64,
    gpu_ops_total: u64,
    in_region: bool,
    region_ops: u64,
    region_parallel: HashMap<LoopId, u64>,
    region: Option<ActiveRegion>,
    prints: Vec<f64>,
    call_depth: usize,
    presence_violations: u64,
}

/// Run compiled `prog` under `plan` with `dev` — the bytecode counterpart
/// of [`vm::run`], producing a bit-identical [`Outcome`].
pub fn run(
    prog: &CompiledProgram,
    plan: &ExecPlan,
    dev: &mut dyn Device,
    cfg: VmConfig,
) -> Result<Outcome> {
    let mut ex = Exec {
        prog,
        plan,
        dev,
        cfg,
        cpu_ops: 0,
        gpu_ops_total: 0,
        in_region: false,
        region_ops: 0,
        region_parallel: HashMap::new(),
        region: None,
        prints: Vec::new(),
        call_depth: 0,
        presence_violations: 0,
    };
    let entry = &prog.funcs[prog.entry];
    if entry.n_params != 0 {
        bail!("`main` must take no parameters");
    }
    ex.exec_func(prog.entry, Vec::new())?;
    let cpu_seconds = ex.cpu_ops as f64 * ex.cfg.cpu_op_ns * 1e-9;
    Ok(Outcome {
        cpu_ops: ex.cpu_ops,
        gpu_ops: ex.gpu_ops_total,
        prints: ex.prints,
        cpu_seconds,
        gpu_seconds: ex.dev.gpu_seconds(),
        energy_j: cpu_seconds * crate::device::HOST_CPU_WATTS + ex.dev.energy_joules(),
        transfers: ex.dev.transfer_stats(),
        presence_violations: ex.presence_violations,
    })
}

/// CPU-only bytecode run — the counterpart of [`vm::run_cpu`].
pub fn run_cpu(prog: &CompiledProgram, cfg: VmConfig) -> Result<Outcome> {
    let plan = ExecPlan::cpu_only();
    let mut dev = NullDevice;
    run(prog, &plan, &mut dev, cfg)
}

/// Read register `r`, mapping an unset named slot to the tree-walker's
/// "undefined variable" error.
fn reg<'v>(f: &CompiledFunc, regs: &'v [Option<Value>], r: Reg) -> Result<&'v Value> {
    match &regs[r as usize] {
        Some(v) => Ok(v),
        None => {
            let name = f.slot_names.get(r as usize).map(|s| s.as_str()).unwrap_or("?");
            bail!("undefined variable `{name}`")
        }
    }
}

fn array_at(f: &CompiledFunc, regs: &[Option<Value>], r: Reg) -> Result<ArrayRef> {
    let name = f.slot_names.get(r as usize).map(|s| s.as_str()).unwrap_or("?");
    match &regs[r as usize] {
        Some(Value::Arr(a)) => Ok(a.clone()),
        Some(_) => bail!("variable `{name}` is not an array"),
        None => bail!("undefined variable `{name}`"),
    }
}

/// Look up a plan-supplied array name (region copy lists).
fn array_by_name(f: &CompiledFunc, regs: &[Option<Value>], name: &str) -> Result<ArrayRef> {
    match f.slots.get(name).and_then(|&s| regs[s as usize].as_ref()) {
        Some(Value::Arr(a)) => Ok(a.clone()),
        Some(_) => bail!("variable `{name}` is not an array"),
        None => bail!("undefined variable `{name}`"),
    }
}

impl<'a> Exec<'a> {
    #[inline]
    fn charge(&mut self, n: u64) -> Result<()> {
        if self.in_region {
            self.region_ops += n;
        } else {
            self.cpu_ops += n;
        }
        if self.cpu_ops + self.region_ops + self.gpu_ops_total > self.cfg.max_ops {
            bail!("operation budget exceeded ({} ops)", self.cfg.max_ops);
        }
        Ok(())
    }

    /// Resolve a loop bound to an `i64` (folded constants skip the frame).
    #[inline]
    fn bound_val(&self, f: &CompiledFunc, regs: &[Option<Value>], b: Bound) -> Result<i64> {
        match b {
            Bound::Const(v) => Ok(v),
            Bound::Reg(r) => reg(f, regs, r)?.as_i64(),
        }
    }

    fn exec_func(&mut self, fi: usize, args: Vec<Value>) -> Result<Option<Value>> {
        let prog = self.prog;
        let f = &prog.funcs[fi];
        let mut regs: Vec<Option<Value>> = vec![None; f.frame];
        for (i, v) in args.into_iter().enumerate() {
            regs[i] = Some(v);
        }
        let mut loops = vec![LoopState { i: 0, end: 0, step: 1 }; f.sites];
        let mut pc = 0usize;
        loop {
            let instr = &f.code[pc];
            pc += 1;
            match instr {
                Instr::Charge(n) => self.charge(*n)?,
                Instr::BoundEvals(n) => {
                    if let Some(c) = &self.cfg.bound_eval_counter {
                        c.fetch_add(*n, Ordering::Relaxed);
                    }
                }
                Instr::LoadInt { dst, v } => regs[*dst as usize] = Some(Value::Int(*v)),
                Instr::LoadFloat { dst, v } => regs[*dst as usize] = Some(Value::Float(*v)),
                Instr::CastInt { dst, src } => {
                    let v = reg(f, &regs, *src)?.as_i64()?;
                    regs[*dst as usize] = Some(Value::Int(v));
                }
                Instr::Copy { dst, src } => {
                    let v = reg(f, &regs, *src)?.clone();
                    regs[*dst as usize] = Some(v);
                }
                Instr::Bin { op, dst, a, b } => {
                    let x = reg(f, &regs, *a)?;
                    let y = reg(f, &regs, *b)?;
                    let v = vm::binary(*op, x, y)?;
                    regs[*dst as usize] = Some(v);
                }
                Instr::Neg { dst, src } => {
                    let v = match reg(f, &regs, *src)? {
                        Value::Int(i) => Value::Int(-i),
                        Value::Float(x) => Value::Float(-x),
                        Value::Arr(_) => bail!("cannot negate an array"),
                    };
                    regs[*dst as usize] = Some(v);
                }
                Instr::Not { dst, src } => {
                    let v = !reg(f, &regs, *src)?.truthy()? as i64;
                    regs[*dst as usize] = Some(Value::Int(v));
                }
                Instr::Truthy { dst, src } => {
                    let v = reg(f, &regs, *src)?.truthy()? as i64;
                    regs[*dst as usize] = Some(Value::Int(v));
                }
                Instr::Intr { f: func, dst, a, b } => {
                    let x = reg(f, &regs, *a)?.as_f64()?;
                    let v = match func {
                        Intrinsic::Sqrt => x.sqrt(),
                        Intrinsic::Exp => x.exp(),
                        Intrinsic::Log => x.ln(),
                        Intrinsic::Sin => x.sin(),
                        Intrinsic::Cos => x.cos(),
                        Intrinsic::Fabs => x.abs(),
                        Intrinsic::Pow => x.powf(reg(f, &regs, *b)?.as_f64()?),
                        Intrinsic::Min => x.min(reg(f, &regs, *b)?.as_f64()?),
                        Intrinsic::Max => x.max(reg(f, &regs, *b)?.as_f64()?),
                        Intrinsic::Floor => x.floor(),
                    };
                    regs[*dst as usize] = Some(Value::Float(v));
                }
                Instr::Len { dst, base, dim } => {
                    let arr = array_at(f, &regs, *base)?;
                    let a = arr.borrow();
                    if *dim >= a.shape.len() {
                        let name = &f.slot_names[*base as usize];
                        bail!("len: dimension {dim} out of range for `{name}`");
                    }
                    let v = a.shape[*dim] as i64;
                    drop(a);
                    regs[*dst as usize] = Some(Value::Int(v));
                }
                Instr::LoadIdx { dst, base, idx } => {
                    let mut buf = [0i64; 8];
                    for (k, &r) in idx.iter().enumerate() {
                        buf[k] = reg(f, &regs, r)?.as_i64()?;
                    }
                    let arr = array_at(f, &regs, *base)?;
                    if !self.in_region {
                        vm::host_read(&mut *self.dev, &arr);
                    }
                    let a = arr.borrow();
                    let off = a.offset(&buf[..idx.len()]).map_err(|e| {
                        anyhow!("array `{}`: {e}", f.slot_names[*base as usize])
                    })?;
                    let v = a.data[off];
                    drop(a);
                    regs[*dst as usize] = Some(Value::Float(v));
                }
                Instr::StoreIdx { base, idx, op, src } => {
                    let mut buf = [0i64; 8];
                    for (k, &r) in idx.iter().enumerate() {
                        buf[k] = reg(f, &regs, r)?.as_i64()?;
                    }
                    let arr = array_at(f, &regs, *base)?;
                    if !self.in_region {
                        if *op != AssignOp::Set {
                            vm::host_read(&mut *self.dev, &arr);
                        }
                        vm::host_write(&mut *self.dev, &arr);
                    }
                    let mut a = arr.borrow_mut();
                    let off = a.offset(&buf[..idx.len()]).map_err(|e| {
                        anyhow!("array `{}`: {e}", f.slot_names[*base as usize])
                    })?;
                    let rv = reg(f, &regs, *src)?.as_f64()?;
                    a.data[off] = match op {
                        AssignOp::Set => rv,
                        AssignOp::Add => a.data[off] + rv,
                        AssignOp::Sub => a.data[off] - rv,
                        AssignOp::Mul => a.data[off] * rv,
                        AssignOp::Div => a.data[off] / rv,
                    };
                }
                Instr::AllocArr { dst, dims } => {
                    let name = &f.slot_names[*dst as usize];
                    let mut shape = Vec::with_capacity(dims.len());
                    for &r in dims.iter() {
                        let ext = reg(f, &regs, r)?.as_i64()?;
                        if ext <= 0 {
                            bail!("array `{name}` has non-positive extent {ext}");
                        }
                        shape.push(ext as usize);
                    }
                    let total: usize = shape.iter().product();
                    if total > 64 * 1024 * 1024 {
                        bail!("array `{name}` too large ({total} elements)");
                    }
                    regs[*dst as usize] = Some(Value::Arr(new_array(shape, vec![0.0; total])));
                }
                Instr::Print { src } => {
                    let v = reg(f, &regs, *src)?.as_f64()?;
                    self.prints.push(v);
                }
                Instr::Jump(to) => pc = *to as usize,
                Instr::JumpIfFalsy { cond, to } => {
                    if !reg(f, &regs, *cond)?.truthy()? {
                        pc = *to as usize;
                    }
                }
                Instr::JumpIfTruthy { cond, to } => {
                    if reg(f, &regs, *cond)?.truthy()? {
                        pc = *to as usize;
                    }
                }
                Instr::Call { name, user, is_lib, args, dst } => {
                    let mut vals = Vec::with_capacity(args.len());
                    for &r in args.iter() {
                        vals.push(reg(f, &regs, r)?.clone());
                    }
                    let ret = self.call(name, *user, *is_lib, vals)?;
                    if let Some(d) = dst {
                        regs[*d as usize] = Some(ret.unwrap_or(Value::Int(0)));
                    }
                }
                Instr::RegionEnter { id, after } => {
                    if !self.in_region {
                        if let Some(region) = self.plan.regions.get(id) {
                            let region = region.clone();
                            if self.enter_region(f, &regs, region)? {
                                // Library region: executed in full
                                pc = *after as usize;
                            }
                        }
                    }
                }
                Instr::LoopInit { site, id, var, save, start, end, step, exit } => {
                    let start_v = self.bound_val(f, &regs, *start)?;
                    let end_v = self.bound_val(f, &regs, *end)?;
                    let step_v = self.bound_val(f, &regs, *step)?;
                    if step_v == 0 {
                        bail!("loop step is zero");
                    }
                    let trips = if step_v > 0 {
                        ((end_v - start_v).max(0) as u64).div_ceil(step_v as u64)
                    } else {
                        ((start_v - end_v).max(0) as u64).div_ceil((-step_v) as u64)
                    };
                    if self.in_region {
                        self.region_parallel.entry(*id).or_insert(trips.max(1));
                    }
                    regs[*save as usize] = regs[*var as usize].clone();
                    loops[*site as usize] = LoopState { i: start_v, end: end_v, step: step_v };
                    let done = if step_v > 0 { start_v >= end_v } else { start_v <= end_v };
                    if done {
                        pc = *exit as usize;
                    } else {
                        self.charge(1)?;
                        regs[*var as usize] = Some(Value::Int(start_v));
                    }
                }
                Instr::LoopNext { site, var, body } => {
                    let st = &mut loops[*site as usize];
                    st.i += st.step;
                    let done = if st.step > 0 { st.i >= st.end } else { st.i <= st.end };
                    if !done {
                        let i = st.i;
                        self.charge(1)?;
                        regs[*var as usize] = Some(Value::Int(i));
                        pc = *body as usize;
                    }
                }
                Instr::LoopRestore { var, save } => {
                    let saved = regs[*save as usize].take();
                    regs[*var as usize] = saved;
                }
                Instr::RegionExit { id } => {
                    if self.region.as_ref().is_some_and(|r| r.region.root == *id) {
                        self.exit_region(f, &regs)?;
                    }
                }
                Instr::Ret { src } => {
                    if let Some(ar) = &self.region {
                        if ar.depth == self.call_depth {
                            bail!("break/continue/return escaped a GPU region");
                        }
                    }
                    let v = match src {
                        Some(r) => Some(reg(f, &regs, *r)?.clone()),
                        None => None,
                    };
                    return Ok(v);
                }
                Instr::End => return Ok(None),
                Instr::Fail(msg) => bail!("{msg}"),
            }
        }
    }

    /// Region entry at a plan-marked `for` root. Returns `true` when the
    /// region was a `Library` replacement and has executed completely
    /// (the caller jumps over the loop); `false` when a `Generic` region
    /// is now active and the loop body should be interpreted in-region.
    fn enter_region(
        &mut self,
        f: &CompiledFunc,
        regs: &[Option<Value>],
        region: GpuRegion,
    ) -> Result<bool> {
        let naive = self.plan.naive_transfers;
        let dest = region.dest;
        // audit static `present` claims against dynamic residency
        // (mirrors the tree-walker; lookup failures defer to the
        // copy_in loop's canonical error)
        if !naive {
            if let Some(tp) = &self.plan.transfers {
                if let Some(rt) = tp.regions.get(&region.root) {
                    for name in &rt.present {
                        if let Ok(arr) = array_by_name(f, regs, name) {
                            if !vm::loc_valid_on(arr.borrow().loc, dest) {
                                self.presence_violations += 1;
                            }
                        }
                    }
                }
            }
        }
        for name in &region.copy_in {
            let arr = array_by_name(f, regs, name)?;
            vm::device_read(&mut *self.dev, &arr, dest, naive);
        }
        self.dev.select_device(dest);
        self.dev.kernel_launch();
        match &region.exec {
            RegionExec::Generic { .. } => {
                self.in_region = true;
                self.region_ops = 0;
                self.region_parallel.clear();
                self.region = Some(ActiveRegion { region, depth: self.call_depth });
                Ok(false)
            }
            RegionExec::Library { name, args } => {
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    let v = f
                        .slots
                        .get(a)
                        .and_then(|&s| regs[s as usize].clone())
                        .ok_or_else(|| anyhow!("library region arg `{a}` undefined"))?;
                    vals.push(v);
                }
                self.dev.select_device(dest);
                self.dev.call_library(name, &vals)?;
                for name in &region.copy_out {
                    let arr = array_by_name(f, regs, name)?;
                    vm::device_write(&mut *self.dev, &arr, dest, naive);
                }
                Ok(true)
            }
        }
    }

    /// Generic-region exit: parallel degree from first-encounter trip
    /// counts, kernel charge, residency updates for the copy-out set.
    fn exit_region(&mut self, f: &CompiledFunc, regs: &[Option<Value>]) -> Result<()> {
        let ar = self.region.take().expect("exit_region without an active region");
        let region = ar.region;
        let parallel: u64 = match &region.exec {
            RegionExec::Generic { parallel_ids } => parallel_ids
                .iter()
                .map(|pid| self.region_parallel.get(pid).copied().unwrap_or(1))
                .product::<u64>()
                .max(1),
            RegionExec::Library { .. } => unreachable!("library regions never activate"),
        };
        let ops = self.region_ops;
        self.gpu_ops_total += ops;
        self.region_ops = 0;
        self.in_region = false;
        self.dev.select_device(region.dest);
        self.dev.charge_generic_kernel(ops, parallel);
        let naive = self.plan.naive_transfers;
        for name in &region.copy_out {
            let arr = array_by_name(f, regs, name)?;
            vm::device_write(&mut *self.dev, &arr, region.dest, naive);
        }
        Ok(())
    }

    /// Call dispatch — same resolution order as the tree-walker: the
    /// plan's GPU-replaced calls first, then the CPU library, then user
    /// functions.
    fn call(
        &mut self,
        name: &str,
        user: Option<u32>,
        is_lib: bool,
        args: Vec<Value>,
    ) -> Result<Option<Value>> {
        if self.plan.gpu_calls.contains(name) {
            if self.in_region {
                bail!("GPU library call `{name}` inside a GPU region");
            }
            let arrs: Vec<ArrayRef> = args
                .iter()
                .filter_map(|v| match v {
                    Value::Arr(a) => Some(a.clone()),
                    _ => None,
                })
                .collect();
            let naive = self.plan.naive_transfers;
            let dest = self.plan.call_dest.get(name).copied().unwrap_or(0);
            for a in &arrs {
                vm::device_read(&mut *self.dev, a, dest, naive);
            }
            self.dev.select_device(dest);
            self.dev.kernel_launch();
            let ret = self.dev.call_library(name, &args)?;
            for a in &arrs {
                vm::device_write(&mut *self.dev, a, dest, naive);
            }
            return Ok(ret);
        }
        if is_lib {
            if self.in_region {
                bail!("library call `{name}` inside a GPU region");
            }
            let arrs: Vec<ArrayRef> = args
                .iter()
                .filter_map(|v| match v {
                    Value::Arr(a) => Some(a.clone()),
                    _ => None,
                })
                .collect();
            for a in &arrs {
                vm::host_read(&mut *self.dev, a);
                vm::host_write(&mut *self.dev, a);
            }
            let (ret, flops) = libs::call(name, &args).unwrap()?;
            self.charge(flops)?;
            return Ok(Some(ret));
        }
        let Some(fi) = user else {
            bail!("call to undefined function `{name}`");
        };
        let g = &self.prog.funcs[fi as usize];
        if g.n_params != args.len() {
            bail!("function `{name}` takes {} arguments, got {}", g.n_params, args.len());
        }
        if self.call_depth > 64 {
            bail!("call depth limit exceeded (recursion?)");
        }
        self.call_depth += 1;
        let r = self.exec_func(fi as usize, args);
        self.call_depth -= 1;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse;
    use crate::workloads;
    use crate::{analysis, vm};
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    fn compile_c(src: &str) -> CompiledProgram {
        let p = parse(src, Lang::C, "t").unwrap();
        compile(&p).unwrap()
    }

    fn assert_same_outcome(a: &Outcome, b: &Outcome) {
        assert_eq!(a.cpu_ops, b.cpu_ops, "cpu_ops");
        assert_eq!(a.gpu_ops, b.gpu_ops, "gpu_ops");
        assert_eq!(a.prints, b.prints, "prints");
        assert_eq!(a.cpu_seconds.to_bits(), b.cpu_seconds.to_bits(), "cpu_seconds");
        assert_eq!(a.gpu_seconds.to_bits(), b.gpu_seconds.to_bits(), "gpu_seconds");
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "energy_j");
        assert_eq!(a.transfers, b.transfers, "transfers");
    }

    #[test]
    fn all_workload_sources_compile() {
        for src in workloads::all() {
            let p = parse(src.code, src.lang, src.app).unwrap();
            let c = compile(&p).unwrap_or_else(|e| panic!("{}/{}: {e}", src.app, src.lang));
            assert!(c.instr_count() > 0);
            assert!(c.func_count() >= 1);
        }
    }

    #[test]
    fn simple_program_matches_tree_walker_bit_for_bit() {
        let src = r#"void main() {
            int n = 32;
            double a[n]; double b[n];
            for (int i = 0; i < n; i++) { a[i] = i * 1.5; }
            for (int i = 0; i < n; i++) { b[i] = a[i] + sqrt(a[i]); }
            double s = 0.0;
            for (int i = 0; i < n; i++) { s += b[i]; }
            printf("%f\n", s);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let c = compile(&p).unwrap();
        let o1 = vm::run_cpu(&p, VmConfig::default()).unwrap();
        let o2 = run_cpu(&c, VmConfig::default()).unwrap();
        assert_same_outcome(&o1, &o2);
    }

    #[test]
    fn offloaded_plan_matches_tree_walker_bit_for_bit() {
        use crate::device::{CostModel, GpuDevice};
        for src in workloads::all() {
            let p = parse(src.code, src.lang, src.app).unwrap();
            let a = analysis::analyze(&p);
            let gene = vec![true; a.gene_loops().len()];
            for naive in [false, true] {
                let plan = analysis::build_plan(&a, &gene, naive);
                let c = compile(&p).unwrap();
                let mut d1 = GpuDevice::simulated(CostModel::default());
                let o1 = vm::run(&p, &plan, &mut d1, VmConfig::default()).unwrap();
                let mut d2 = GpuDevice::simulated(CostModel::default());
                let o2 = run(&c, &plan, &mut d2, VmConfig::default()).unwrap();
                assert_same_outcome(&o1, &o2);
            }
        }
    }

    #[test]
    fn literal_loop_bounds_fold_to_zero_dynamic_evals() {
        // satellite bugfix regression: a 10k-iteration counted loop with
        // literal bounds must perform zero dynamic bound evaluations in
        // the bytecode engine; the tree-walker's generic eval path pays
        // them on every loop entry.
        let src = r#"void main() {
            double s = 0.0;
            for (int r = 0; r < 100; r++) {
                for (int i = 0; i < 100; i++) { s += 1.0; }
            }
            printf("%f\n", s);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let c = compile(&p).unwrap();

        let counter = Arc::new(AtomicU64::new(0));
        let cfg = VmConfig { bound_eval_counter: Some(counter.clone()), ..Default::default() };
        let o = run_cpu(&c, cfg).unwrap();
        assert_eq!(o.prints, vec![10_000.0]);
        assert_eq!(
            counter.load(Ordering::Relaxed),
            0,
            "literal bounds must be folded at compile time"
        );

        let tree_counter = Arc::new(AtomicU64::new(0));
        let cfg = VmConfig { bound_eval_counter: Some(tree_counter.clone()), ..Default::default() };
        let o2 = vm::run_cpu(&p, cfg).unwrap();
        assert_eq!(o.prints, o2.prints);
        // outer entry (3 bounds) + 100 inner entries (3 bounds each)
        assert_eq!(tree_counter.load(Ordering::Relaxed), 303);
    }

    #[test]
    fn dynamic_loop_bounds_are_counted() {
        let src = r#"void main() {
            int n = 50;
            double s = 0.0;
            for (int i = 0; i < n; i++) { s += 1.0; }
            printf("%f\n", s);
        }"#;
        let c = compile_c(src);
        let counter = Arc::new(AtomicU64::new(0));
        let cfg = VmConfig { bound_eval_counter: Some(counter.clone()), ..Default::default() };
        run_cpu(&c, cfg).unwrap();
        // start and step are literals; only `n` needs a dynamic eval
        assert_eq!(counter.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn deeply_nested_ir_fails_to_compile_cleanly() {
        // programmatically built IR deeper than any front end emits: the
        // compiler must error, not overflow its stack
        let mut e = Expr::int(1);
        for _ in 0..100_000 {
            e = Expr::Unary { op: UnOp::Neg, operand: Box::new(e) };
        }
        let p = Program {
            lang: Lang::C,
            name: "deep".into(),
            functions: vec![Function {
                name: "main".into(),
                params: vec![],
                ret: Type::Void,
                body: vec![Stmt::Print(e)],
            }],
        };
        let err = compile(&p).unwrap_err();
        assert!(err.to_string().contains("deep"), "{err}");
    }

    #[test]
    fn break_continue_and_while_semantics_match() {
        let src = r#"void main() {
            int i = 0; int s = 0;
            while (1) {
                i++;
                if (i % 2 == 0) { continue; }
                if (i > 9) { break; }
                s += i;
            }
            for (int j = 0; j < 10; j++) {
                if (j == 5) { break; }
                s += j;
            }
            printf("%d\n", s);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let c = compile(&p).unwrap();
        let o1 = vm::run_cpu(&p, VmConfig::default()).unwrap();
        let o2 = run_cpu(&c, VmConfig::default()).unwrap();
        assert_same_outcome(&o1, &o2);
        assert_eq!(o2.prints, vec![35.0]); // 25 + (0+1+2+3+4)
    }

    #[test]
    fn loop_var_save_restore_matches() {
        let src = r#"void main() {
            int i = 99;
            for (int i = 0; i < 3; i++) { }
            printf("%d\n", i);
        }"#;
        let c = compile_c(src);
        let o = run_cpu(&c, VmConfig::default()).unwrap();
        assert_eq!(o.prints, vec![99.0]);
    }

    #[test]
    fn errors_match_tree_walker() {
        for src in [
            "void main() { double a[4]; a[5] = 1.0; }",
            "void main() { int x = 1 / 0; }",
            "void main() { for (int i = 0; i < 10; i = i + 0) { } }",
            "void main() { printf(\"%f\\n\", nothere); }",
            "int f(int x) { return f(x + 1); } void main() { int y = f(0); }",
        ] {
            let p = parse(src, Lang::C, "t").unwrap();
            let c = compile(&p).unwrap();
            let e1 = vm::run_cpu(&p, VmConfig::default()).unwrap_err();
            let e2 = run_cpu(&c, VmConfig::default()).unwrap_err();
            assert_eq!(e1.to_string(), e2.to_string(), "src: {src}");
        }
    }

    #[test]
    fn op_budget_enforced_in_bytecode() {
        let c = compile_c("void main() { double s = 0.0; while (1) { s += 1.0; } }");
        let err = run_cpu(&c, VmConfig { max_ops: 10_000, ..Default::default() }).unwrap_err();
        assert!(err.to_string().contains("budget"), "{err}");
    }

    #[test]
    fn short_circuit_charges_match() {
        let src = r#"void main() {
            int n = 20;
            int hits = 0;
            for (int i = 0; i < n; i++) {
                if (i % 2 == 0 && i % 3 == 0) { hits += 1; }
                if (i % 5 == 0 || i % 7 == 0) { hits += 1; }
            }
            printf("%d\n", hits);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let c = compile(&p).unwrap();
        let o1 = vm::run_cpu(&p, VmConfig::default()).unwrap();
        let o2 = run_cpu(&c, VmConfig::default()).unwrap();
        assert_same_outcome(&o1, &o2);
    }

    #[test]
    fn user_functions_and_library_calls_match() {
        let src = r#"
        double total(double a[], int n) {
            double s = 0.0;
            for (int i = 0; i < n; i++) { s += a[i]; }
            return s;
        }
        void main() {
            int n = 8;
            double a[n][n]; double b[n][n]; double c[n][n];
            seed_fill(a, 1);
            seed_fill(b, 2);
            matmul(a, b, c, n);
            double x[4];
            x[0] = 1.0; x[1] = 2.0; x[2] = 3.0; x[3] = 4.0;
            printf("%f\n", total(x, 4) + c[0][0]);
        }"#;
        let p = parse(src, Lang::C, "t").unwrap();
        let c = compile(&p).unwrap();
        let o1 = vm::run_cpu(&p, VmConfig::default()).unwrap();
        let o2 = run_cpu(&c, VmConfig::default()).unwrap();
        assert_same_outcome(&o1, &o2);
    }
}
