//! Parallel measurement engine: the verification environment's worker
//! pool plus a persistent cross-run measurement cache.
//!
//! The paper's bottleneck is the verification step — every candidate
//! offload pattern is compiled and measured in a trial environment, and
//! Yamato's follow-ups (arXiv:2002.12115, arXiv:2011.12431) both attack
//! that budget. This module is the reproduction's equivalent: the GA hands
//! over each generation's distinct unmeasured genes as one batch
//! ([`crate::ga::BatchEvaluator`]) and the engine fans the batch out over
//! `workers` OS threads. Every worker owns its own device pool built from
//! a [`MultiDeviceFactory`] — one member per destination of the
//! heterogeneous device set, so mixed placements measure on the worker's
//! own devices (PJRT clients are not `Send`, so devices never cross
//! threads), while the program, the [`Measurer`] baseline and the
//! gene→plan closure are shared read-only. The pool serves simulated
//! backends; PJRT-backed engines measure serially on the caller's
//! long-lived device, whose warm executable cache is worth more there
//! than thread parallelism (and whose backend is the one the cache
//! fingerprint was probed from).
//!
//! **Determinism:** results are written by batch index, never by
//! completion order, and the gene→time memoization lives in keyed maps —
//! so a fixed seed produces bit-identical search results (best gene,
//! best time, full `GenStats` history) at any worker count.
//!
//! **Caching:** measured times are memoized under
//! `(program fingerprint, target kind, gene)` in a [`MeasurementCache`]
//! that can be shared between coordinators (the adaptive per-target runs,
//! the batch front end's worker pool) and persisted to disk, so repeated
//! offload requests for a known program never re-measure a known pattern.
//! The fingerprint folds in every knob that affects a recorded fitness
//! (cost model, VM limits, tolerance, transfer policy, the search-space
//! tag, the heterogeneous device set and the power weight), which is
//! what makes a cache hit semantically safe.

use crate::bytecode::CompiledProgram;
use crate::config::Config;
use crate::device::{DeviceStats, MultiDevice, MultiDeviceFactory, TargetKind};
use crate::ga::BatchEvaluator;
use crate::ir::Program;
use crate::measure::{Measurement, Measurer};
use crate::util::fxhash::FxHasher;
use crate::vm::ExecPlan;
use anyhow::Result;
use std::collections::HashMap;
use std::hash::Hasher;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// The shared gene→plan mapping. Must be `Sync`: pool workers call it
/// concurrently to build their own `ExecPlan`s.
pub type PlanBuilder<'a> = &'a (dyn Fn(&[bool]) -> ExecPlan + Sync);

// Compile-time proof of the sharing contract the pool relies on: worker
// threads hold `&Program`, `&Measurer`, `&DeviceFactory` and move owned
// plans/stats back.
#[allow(dead_code)]
fn _sharing_contract() {
    fn sync<T: Sync>() {}
    fn send<T: Send>() {}
    sync::<Program>();
    sync::<Measurer>();
    sync::<MultiDeviceFactory>();
    send::<ExecPlan>();
    send::<DeviceStats>();
    send::<MeasurementCache>();
    send::<CompiledCache>();
    sync::<CompiledProgram>();
}

// ---------------------------------------------------------------------------
// persistent measurement cache
// ---------------------------------------------------------------------------

/// Render a gene as its canonical `0`/`1` string (`-` for the empty gene,
/// so cache-file fields are never empty).
fn gene_str(gene: &[bool]) -> String {
    if gene.is_empty() {
        return "-".to_string();
    }
    gene.iter().map(|&b| if b { '1' } else { '0' }).collect()
}

/// Cache key: `(program fingerprint, target kind, gene)` rendered as one
/// string — also the on-disk line prefix.
fn cache_key(fingerprint: u64, target: TargetKind, gene: &[bool]) -> String {
    format!("{fingerprint:016x}|{}|{}", target.name(), gene_str(gene))
}

/// Cross-run measurement memo. In-memory always; optionally backed by a
/// line-oriented file (`fingerprint|target|gene|seconds`) so a restarted
/// coordinator resumes with every previously measured pattern warm.
#[derive(Debug, Default)]
pub struct MeasurementCache {
    entries: HashMap<String, f64>,
    path: Option<PathBuf>,
    dirty: bool,
    /// lifetime lookup counters (not persisted) — the service's stats
    /// endpoint reports these across every coordinator sharing the cache
    hits: u64,
    misses: u64,
}

impl MeasurementCache {
    /// Purely in-memory cache (still shared across coordinators).
    pub fn in_memory() -> MeasurementCache {
        MeasurementCache::default()
    }

    /// Cache backed by `path`. A missing file is an empty cache; malformed
    /// lines are skipped (a torn write must never poison the search).
    pub fn open(path: impl AsRef<Path>) -> MeasurementCache {
        let path = path.as_ref().to_path_buf();
        let mut entries = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            for line in text.lines() {
                if line.starts_with('#') || line.trim().is_empty() {
                    continue;
                }
                if let Some((key, time)) = line.rsplit_once('|') {
                    if key.split('|').count() == 3 {
                        if let Ok(t) = time.parse::<f64>() {
                            entries.insert(key.to_string(), t);
                        }
                    }
                }
            }
        }
        MeasurementCache { entries, path: Some(path), ..MeasurementCache::default() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.entries.get(key).copied()
    }

    /// Counted lookup: like [`MeasurementCache::get`] but bumps the
    /// hit/miss counters (what the engines use, so shared-cache stats
    /// reflect real traffic).
    pub fn lookup(&mut self, key: &str) -> Option<f64> {
        let r = self.entries.get(key).copied();
        if r.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        r
    }

    pub fn hit_count(&self) -> u64 {
        self.hits
    }

    pub fn miss_count(&self) -> u64 {
        self.misses
    }

    pub fn insert(&mut self, key: String, time: f64) {
        self.entries.insert(key, time);
        self.dirty = true;
    }

    /// Write the cache file (no-op for in-memory caches or when nothing
    /// changed since the last save). `f64`'s `Display` is shortest-exact,
    /// and `inf` round-trips, so invalid patterns persist too.
    pub fn save(&mut self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        if !self.dirty {
            return Ok(());
        }
        let mut keys: Vec<&String> = self.entries.keys().collect();
        keys.sort();
        let mut out = String::from("# envadapt measurement cache v1: fingerprint|target|gene|seconds\n");
        for k in keys {
            out.push_str(k);
            out.push('|');
            out.push_str(&format!("{}\n", self.entries[k]));
        }
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, out)?;
        self.dirty = false;
        Ok(())
    }
}

/// The cache as shared between coordinators and pool workers.
pub type SharedCache = Arc<Mutex<MeasurementCache>>;

pub fn shared(cache: MeasurementCache) -> SharedCache {
    Arc::new(Mutex::new(cache))
}

/// The cache a [`Config`] asks for: disk-backed when `cache_path` is set.
pub fn cache_for(cfg: &Config) -> SharedCache {
    match &cfg.cache_path {
        Some(p) => shared(MeasurementCache::open(p)),
        None => shared(MeasurementCache::in_memory()),
    }
}

// ---------------------------------------------------------------------------
// compiled-program cache
// ---------------------------------------------------------------------------

/// Hash of the program structure alone — the compiled bytecode depends on
/// nothing else (the `ExecPlan`/gene is consulted only at region-marker
/// ops at run time), so unlike [`fingerprint`] this key deliberately
/// ignores every cost-model and VM knob.
pub fn program_hash(prog: &Program) -> u64 {
    let mut h = FxHasher::default();
    h.write(format!("{prog:?}").as_bytes());
    h.finish()
}

/// Memoized IR→bytecode compilations, keyed by [`program_hash`]. One
/// compiled artifact serves every gene evaluation, every search phase and
/// every repeat request for the same program; uncompilable programs (the
/// depth guard) are remembered as `None` so the measurer's tree-walker
/// fallback is not re-attempted through the compiler on every request.
#[derive(Default)]
pub struct CompiledCache {
    entries: HashMap<u64, Option<Arc<CompiledProgram>>>,
    hits: usize,
    compiles: usize,
}

impl CompiledCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The compiled form of `prog`, compiling on first sight. `None` means
    /// the compiler declined (callers fall back to the tree-walker).
    pub fn get_or_compile(&mut self, prog: &Program) -> Option<Arc<CompiledProgram>> {
        let key = program_hash(prog);
        if let Some(c) = self.entries.get(&key) {
            self.hits += 1;
            return c.clone();
        }
        self.compiles += 1;
        let compiled = crate::bytecode::compile(prog).ok().map(Arc::new);
        self.entries.insert(key, compiled.clone());
        compiled
    }

    /// Cache hits since creation (test/diagnostic hook).
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Compilation attempts since creation (test/diagnostic hook).
    pub fn compiles(&self) -> usize {
        self.compiles
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The compiled-program cache as shared between coordinators and sessions.
pub type SharedCompiledCache = Arc<Mutex<CompiledCache>>;

pub fn compiled_shared() -> SharedCompiledCache {
    Arc::new(Mutex::new(CompiledCache::new()))
}

// ---------------------------------------------------------------------------
// program fingerprinting
// ---------------------------------------------------------------------------

/// Fingerprint of everything that determines a measured time besides the
/// gene itself: the program (canonical IR rendering), every cost-model and
/// VM parameter, the results-check tolerance, the transfer policy, a
/// search-space tag (`"loops"` vs `"funcblock"` — both encode plans as
/// bit-vectors, so they must never share keys), and any extra context
/// (e.g. the chosen function-block candidates the loop GA builds on).
///
/// `cfg.use_pjrt` is hashed as the numerics backend: callers must pass
/// the backend that will *actually* run (the coordinator probes its
/// device, since `with_runtime` can fall back to simulation) — otherwise
/// fallback-run times could later be reused as if they were PJRT results.
/// For PJRT backends the caller also appends the device's artifact
/// inventory to `extra`: library calls fall back per-kernel when an
/// artifact is missing, so the inventory shapes the measured numerics.
pub fn fingerprint(prog: &Program, cfg: &Config, space: &str, extra: &[&str]) -> u64 {
    let mut h = FxHasher::default();
    h.write(format!("{prog:?}").as_bytes());
    h.write(space.as_bytes());
    for e in extra {
        h.write(e.as_bytes());
        h.write_u8(0x1f); // separator: ["ab","c"] ≠ ["a","bc"]
    }
    let c = &cfg.cost;
    for x in [
        c.launch_s,
        c.h2d_bytes_per_s,
        c.d2h_bytes_per_s,
        c.transfer_latency_s,
        c.gpu_op_ns,
        c.lib_flop_ns,
        c.busy_watts,
        cfg.vm.cpu_op_ns,
        cfg.tolerance,
        cfg.power_weight,
    ] {
        h.write_u64(x.to_bits());
    }
    h.write_u64(c.gpu_lanes);
    h.write_u64(cfg.vm.max_ops);
    h.write_u8(cfg.naive_transfers as u8);
    // the transfer-opt knob changes how plans charge transfers (naive
    // per-region accounting when off), so cached times must not cross it
    h.write_u8(cfg.no_transfer_opt as u8);
    h.write_u8(cfg.use_pjrt as u8);
    // the destination set defines what each gene bit *means* (slot width
    // and device numbering), so two searches over different sets must
    // never share cache entries even for identical bit strings
    for d in cfg.effective_devices() {
        h.write(d.name().as_bytes());
        h.write_u8(0x1e);
    }
    h.finish()
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

/// One search phase's measurement backend: batch evaluation over a device
/// worker pool with cross-run caching. Borrows the program, the measurer
/// and the plan builder for the phase's lifetime; owns its (cheap) device
/// factory and a handle on the shared cache.
pub struct MeasurementEngine<'a> {
    prog: &'a Program,
    measurer: &'a Measurer,
    factory: MultiDeviceFactory,
    plan: PlanBuilder<'a>,
    workers: usize,
    target: TargetKind,
    fingerprint: u64,
    cache: SharedCache,
    /// the caller's long-lived device pool for the serial path and full
    /// measurements. Borrowed (not built here) so the PJRT executable
    /// cache stays warm across phases and applications, exactly like the
    /// pre-engine single-device coordinator — and so the backend the
    /// caller probed for the fingerprint is the backend that measures.
    serial_dev: &'a mut MultiDevice,
    /// weight of modeled energy in the recorded fitness (0 = pure time);
    /// folded into the cache fingerprint by every caller
    power_weight: f64,
    stats: DeviceStats,
    measured: usize,
    cache_hits: usize,
}

impl<'a> MeasurementEngine<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        prog: &'a Program,
        measurer: &'a Measurer,
        factory: MultiDeviceFactory,
        plan: PlanBuilder<'a>,
        workers: usize,
        target: TargetKind,
        fingerprint: u64,
        cache: SharedCache,
        serial_dev: &'a mut MultiDevice,
        power_weight: f64,
    ) -> MeasurementEngine<'a> {
        MeasurementEngine {
            prog,
            measurer,
            factory,
            plan,
            workers: workers.max(1),
            target,
            fingerprint,
            cache,
            serial_dev,
            power_weight,
            stats: DeviceStats::default(),
            measured: 0,
            cache_hits: 0,
        }
    }

    /// Patterns actually measured by this engine (cache misses).
    pub fn measured(&self) -> usize {
        self.measured
    }

    /// Patterns answered from the shared cache.
    pub fn cache_hits(&self) -> usize {
        self.cache_hits
    }

    /// Merged device counters across the serial device and every pool
    /// worker this engine has run.
    pub fn stats(&self) -> DeviceStats {
        self.stats
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Persist the shared cache if it is disk-backed.
    pub fn flush_cache(&self) -> Result<()> {
        self.cache.lock().unwrap().save()
    }

    /// Measure one gene (cached).
    pub fn measure_one(&mut self, gene: &[bool]) -> f64 {
        self.measure_batch(&[gene.to_vec()])[0]
    }

    /// Full [`Measurement`] (outcome, failure reason, wall time) for one
    /// gene — used for final verification and the winning function-block
    /// subset, where the GA-time scalar is not enough. Always runs on the
    /// serial device (the outcome itself is not cached), but feeds the
    /// time back into the cache.
    pub fn measure_full(&mut self, gene: &[bool]) -> Measurement {
        let plan = (self.plan)(gene);
        self.serial_dev.reset();
        let m = self.measurer.measure(self.prog, &plan, &mut *self.serial_dev);
        let dstats = self.serial_dev.stats();
        self.stats.merge(&dstats);
        self.measured += 1;
        let key = cache_key(self.fingerprint, self.target, gene);
        self.cache.lock().unwrap().insert(key, m.ga_score(self.power_weight));
        m
    }

    /// Measure a batch of genes: cache lookups first, then the misses
    /// either serially (one warm device) or across the worker pool.
    /// Results line up index-for-index with `genes`; duplicates within a
    /// batch are measured once.
    pub fn measure_batch(&mut self, genes: &[Vec<bool>]) -> Vec<f64> {
        let mut out = vec![0.0f64; genes.len()];
        let keys: Vec<String> =
            genes.iter().map(|g| cache_key(self.fingerprint, self.target, g)).collect();

        // resolve cache hits and in-batch duplicates
        let mut todo: Vec<usize> = Vec::new();
        let mut dups: Vec<(usize, usize)> = Vec::new();
        {
            let mut cache = self.cache.lock().unwrap();
            let mut first: HashMap<&str, usize> = HashMap::new();
            for (i, k) in keys.iter().enumerate() {
                if let Some(t) = cache.lookup(k) {
                    out[i] = t;
                    self.cache_hits += 1;
                } else if let Some(&j) = first.get(k.as_str()) {
                    dups.push((i, j));
                } else {
                    first.insert(k, i);
                    todo.push(i);
                }
            }
        }

        if !todo.is_empty() {
            // The pool is simulated-only: a PJRT pool worker's
            // `with_runtime` can silently fall back to simulation (client
            // exhaustion, missing artifacts), which would poison the
            // cache with simulated times under a PJRT fingerprint. PJRT
            // measures serially on the caller's warm device, whose
            // executable cache beats thread parallelism there anyway.
            let use_pool = self.workers > 1 && todo.len() > 1 && !self.factory.use_pjrt();
            let results: Vec<(f64, DeviceStats)> = if use_pool {
                self.measure_parallel(genes, &todo)
            } else {
                todo.iter().map(|&i| self.measure_serial(&genes[i])).collect()
            };
            let mut cache = self.cache.lock().unwrap();
            for (&i, (t, dstats)) in todo.iter().zip(&results) {
                out[i] = *t;
                self.stats.merge(dstats);
                self.measured += 1;
                cache.insert(keys[i].clone(), *t);
            }
        }
        for (i, j) in dups {
            out[i] = out[j];
        }
        out
    }

    fn measure_serial(&mut self, gene: &[bool]) -> (f64, DeviceStats) {
        let plan = (self.plan)(gene);
        self.serial_dev.reset();
        let m = self.measurer.measure(self.prog, &plan, &mut *self.serial_dev);
        (m.ga_score(self.power_weight), self.serial_dev.stats())
    }

    /// Fan `todo` (indices into `genes`) out over the pool. Workers pull
    /// indices from a shared counter and write into per-index slots, so
    /// scheduling order cannot affect which result lands where.
    ///
    /// Only reached for simulated factories (see `measure_batch`), so the
    /// per-batch device rebuild is free — a simulated device is a handful
    /// of floats. Scoped threads keep every lifetime simple and `Device`
    /// never crosses threads. A persistent worker pool (long-lived
    /// threads owning their devices) is the natural upgrade if a
    /// thread-safe PJRT backend ever makes pooled PJRT measurement
    /// worthwhile.
    fn measure_parallel(&self, genes: &[Vec<bool>], todo: &[usize]) -> Vec<(f64, DeviceStats)> {
        let n_workers = self.workers.min(todo.len());
        let factory = &self.factory;
        let plan = self.plan;
        let measurer = self.measurer;
        let prog = self.prog;
        let power_weight = self.power_weight;
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<(f64, DeviceStats)>>> =
            (0..todo.len()).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..n_workers {
                let next = &next;
                let slots = &slots;
                scope.spawn(move || {
                    // one device per worker, built inside the worker's
                    // thread (PJRT clients are not Send)
                    let mut dev = factory.build();
                    loop {
                        let k = next.fetch_add(1, Ordering::Relaxed);
                        if k >= todo.len() {
                            break;
                        }
                        let gene = &genes[todo[k]];
                        let exec_plan = (plan)(gene);
                        dev.reset();
                        let m = measurer.measure(prog, &exec_plan, &mut dev);
                        *slots[k].lock().unwrap() =
                            Some((m.ga_score(power_weight), dev.stats()));
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("pool worker filled its slot"))
            .collect()
    }
}

impl BatchEvaluator for MeasurementEngine<'_> {
    fn measure_batch(&mut self, genes: &[Vec<bool>]) -> Vec<f64> {
        MeasurementEngine::measure_batch(self, genes)
    }
}

impl BatchEvaluator for &mut MeasurementEngine<'_> {
    fn measure_batch(&mut self, genes: &[Vec<bool>]) -> Vec<f64> {
        MeasurementEngine::measure_batch(&mut **self, genes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::CostModel;
    use crate::frontend::parse;
    use crate::ir::Lang;
    use crate::vm::VmConfig;
    use crate::{analysis, ga};

    const SRC: &str = r#"void main() {
        int n = 256;
        double x[n]; double y[n]; double z[n];
        seed_fill(x, 3);
        for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0 + 1.0; }
        for (int i = 0; i < n; i++) { z[i] = y[i] + x[i]; }
        for (int i = 0; i < n; i++) { x[i] = z[i] * 0.5; }
        double s = 0.0;
        for (int i = 0; i < n; i++) { s += x[i] + y[i] + z[i]; }
        printf("%f\n", s);
    }"#;

    struct Fixture {
        prog: Program,
        analysis: crate::analysis::ProgramAnalysis,
        measurer: Measurer,
        cfg: Config,
    }

    fn fixture() -> Fixture {
        let prog = parse(SRC, Lang::C, "engine_test").unwrap();
        let analysis = analysis::analyze(&prog);
        let measurer = Measurer::new(&prog, VmConfig::default(), 1e-3).unwrap();
        let cfg = Config::fast_sim();
        Fixture { prog, analysis, measurer, cfg }
    }

    fn sim_dev() -> MultiDevice {
        MultiDeviceFactory::single(CostModel::default(), false).build()
    }

    fn engine<'a>(
        f: &'a Fixture,
        plan: PlanBuilder<'a>,
        workers: usize,
        cache: SharedCache,
        dev: &'a mut MultiDevice,
    ) -> MeasurementEngine<'a> {
        let fp = fingerprint(&f.prog, &f.cfg, "loops", &[]);
        MeasurementEngine::new(
            &f.prog,
            &f.measurer,
            MultiDeviceFactory::single(CostModel::default(), false),
            plan,
            workers,
            TargetKind::Gpu,
            fp,
            cache,
            dev,
            0.0,
        )
    }

    #[test]
    fn batch_results_match_serial_measurement_exactly() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        assert!(len >= 3);
        let genes: Vec<Vec<bool>> =
            (0..1usize << len).map(|b| (0..len).map(|k| b >> k & 1 == 1).collect()).collect();

        let mut d1 = sim_dev();
        let mut serial = engine(&f, &plan, 1, shared(MeasurementCache::in_memory()), &mut d1);
        let t_serial = serial.measure_batch(&genes);
        let mut d2 = sim_dev();
        let mut pooled = engine(&f, &plan, 4, shared(MeasurementCache::in_memory()), &mut d2);
        let t_pooled = pooled.measure_batch(&genes);
        assert_eq!(t_serial, t_pooled, "worker count must not change modeled times");
        assert_eq!(serial.measured(), genes.len());
        assert_eq!(pooled.measured(), genes.len());
        // merged pool stats match the serial device's accumulation
        assert_eq!(serial.stats().launches, pooled.stats().launches);
        assert_eq!(serial.stats().h2d_bytes, pooled.stats().h2d_bytes);
    }

    #[test]
    fn in_batch_duplicates_measured_once() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let g = vec![true; len];
        let mut dev = sim_dev();
        let mut eng = engine(&f, &plan, 2, shared(MeasurementCache::in_memory()), &mut dev);
        let times = eng.measure_batch(&[g.clone(), g.clone(), g]);
        assert_eq!(times[0], times[1]);
        assert_eq!(times[1], times[2]);
        assert_eq!(eng.measured(), 1);
    }

    #[test]
    fn shared_cache_prevents_remeasurement() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let genes: Vec<Vec<bool>> = vec![vec![false; len], vec![true; len]];
        let cache = shared(MeasurementCache::in_memory());

        let mut d1 = sim_dev();
        let mut first = engine(&f, &plan, 2, cache.clone(), &mut d1);
        let t1 = first.measure_batch(&genes);
        assert_eq!(first.measured(), 2);

        let mut d2 = sim_dev();
        let mut second = engine(&f, &plan, 2, cache, &mut d2);
        let t2 = second.measure_batch(&genes);
        assert_eq!(t1, t2);
        assert_eq!(second.measured(), 0, "everything should come from the cache");
        assert_eq!(second.cache_hits(), 2);
    }

    #[test]
    fn cache_counters_track_hits_and_misses() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let genes: Vec<Vec<bool>> = vec![vec![false; len], vec![true; len]];
        let cache = shared(MeasurementCache::in_memory());
        let mut d1 = sim_dev();
        let mut first = engine(&f, &plan, 1, cache.clone(), &mut d1);
        first.measure_batch(&genes);
        {
            let c = cache.lock().unwrap();
            assert_eq!(c.miss_count(), 2);
            assert_eq!(c.hit_count(), 0);
        }
        let mut d2 = sim_dev();
        let mut second = engine(&f, &plan, 1, cache.clone(), &mut d2);
        second.measure_batch(&genes);
        let c = cache.lock().unwrap();
        assert_eq!(c.miss_count(), 2);
        assert_eq!(c.hit_count(), 2);
    }

    #[test]
    fn different_targets_never_share_cache_entries() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let gene = vec![vec![true; len]];
        let cache = shared(MeasurementCache::in_memory());
        let fp = fingerprint(&f.prog, &f.cfg, "loops", &[]);

        let gpu_factory = MultiDeviceFactory::for_targets(&[TargetKind::Gpu], false);
        let mut gpu_dev = gpu_factory.build();
        let mut gpu = MeasurementEngine::new(
            &f.prog,
            &f.measurer,
            gpu_factory,
            &plan,
            1,
            TargetKind::Gpu,
            fp,
            cache.clone(),
            &mut gpu_dev,
            0.0,
        );
        let t_gpu = gpu.measure_batch(&gene)[0];
        let mc_factory = MultiDeviceFactory::for_targets(&[TargetKind::ManyCore], false);
        let mut mc_dev = mc_factory.build();
        let mut mc = MeasurementEngine::new(
            &f.prog,
            &f.measurer,
            mc_factory,
            &plan,
            1,
            TargetKind::ManyCore,
            fp,
            cache,
            &mut mc_dev,
            0.0,
        );
        let t_mc = mc.measure_batch(&gene)[0];
        assert_eq!(mc.measured(), 1, "many-core must not hit the GPU's entry");
        assert_ne!(t_gpu, t_mc, "different cost models, different times");
    }

    #[test]
    fn cache_round_trips_through_disk() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let mut one_on = vec![false; len];
        one_on[0] = true;
        let genes: Vec<Vec<bool>> = vec![vec![false; len], vec![true; len], one_on];
        let path = std::env::temp_dir()
            .join(format!("envadapt_cache_test_{}.txt", std::process::id()));
        let _ = std::fs::remove_file(&path);

        let mut dev = sim_dev();
        let mut eng = engine(&f, &plan, 1, shared(MeasurementCache::open(&path)), &mut dev);
        let times = eng.measure_batch(&genes);
        eng.flush_cache().unwrap();

        let reloaded = MeasurementCache::open(&path);
        assert_eq!(reloaded.len(), genes.len());
        let fp = fingerprint(&f.prog, &f.cfg, "loops", &[]);
        for (g, t) in genes.iter().zip(&times) {
            let got = reloaded.get(&cache_key(fp, TargetKind::Gpu, g));
            assert_eq!(got, Some(*t), "gene {g:?}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn infinite_times_survive_the_disk_format() {
        let path = std::env::temp_dir()
            .join(format!("envadapt_cache_inf_{}.txt", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut c = MeasurementCache::open(&path);
        c.insert(cache_key(7, TargetKind::Fpga, &[true, false]), f64::INFINITY);
        c.insert(cache_key(7, TargetKind::Fpga, &[false, true]), 1.25e-3);
        c.insert(cache_key(7, TargetKind::Fpga, &[]), 0.75);
        c.save().unwrap();
        let r = MeasurementCache::open(&path);
        assert_eq!(r.get(&cache_key(7, TargetKind::Fpga, &[true, false])), Some(f64::INFINITY));
        assert_eq!(r.get(&cache_key(7, TargetKind::Fpga, &[false, true])), Some(1.25e-3));
        assert_eq!(r.get(&cache_key(7, TargetKind::Fpga, &[])), Some(0.75), "empty gene key");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_cache_lines_are_skipped() {
        let path = std::env::temp_dir()
            .join(format!("envadapt_cache_bad_{}.txt", std::process::id()));
        std::fs::write(
            &path,
            "# header\ngarbage\nonly|two\nab|gpu|101|not_a_number\n00000000000000ab|gpu|101|0.5\n",
        )
        .unwrap();
        let c = MeasurementCache::open(&path);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get("00000000000000ab|gpu|101"), Some(0.5));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fingerprint_separates_programs_configs_and_spaces() {
        let f = fixture();
        let prog2 = parse(&SRC.replace("* 2.0", "* 3.0"), Lang::C, "engine_test").unwrap();
        let base = fingerprint(&f.prog, &f.cfg, "loops", &[]);
        assert_ne!(base, fingerprint(&prog2, &f.cfg, "loops", &[]), "program change");
        assert_ne!(base, fingerprint(&f.prog, &f.cfg, "funcblock", &[]), "space change");
        assert_ne!(base, fingerprint(&f.prog, &f.cfg, "loops", &["fb0"]), "context change");
        let mut cfg2 = f.cfg.clone();
        cfg2.naive_transfers = true;
        assert_ne!(base, fingerprint(&f.prog, &cfg2, "loops", &[]), "transfer policy change");
        let mut cfg2b = f.cfg.clone();
        cfg2b.no_transfer_opt = true;
        assert_ne!(base, fingerprint(&f.prog, &cfg2b, "loops", &[]), "transfer-opt knob change");
        assert_ne!(
            fingerprint(&f.prog, &cfg2, "loops", &[]),
            fingerprint(&f.prog, &cfg2b, "loops", &[]),
            "ablation and knob are distinct cache spaces"
        );
        let mut cfg3 = f.cfg.clone();
        cfg3.cost.gpu_op_ns *= 2.0;
        assert_ne!(base, fingerprint(&f.prog, &cfg3, "loops", &[]), "cost model change");
        let mut cfg4 = f.cfg.clone();
        cfg4.devices = vec![TargetKind::Gpu, TargetKind::ManyCore];
        assert_ne!(base, fingerprint(&f.prog, &cfg4, "loops", &[]), "device set change");
        let mut cfg5 = f.cfg.clone();
        cfg5.power_weight = 0.25;
        assert_ne!(base, fingerprint(&f.prog, &cfg5, "loops", &[]), "power weight change");
        // extra-context concatenation must not be ambiguous
        assert_ne!(
            fingerprint(&f.prog, &f.cfg, "loops", &["ab", "c"]),
            fingerprint(&f.prog, &f.cfg, "loops", &["a", "bc"])
        );
    }

    #[test]
    fn ga_over_engine_is_deterministic_across_worker_counts() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let cfg = ga::GaConfig { population: 8, generations: 8, ..Default::default() };
        let mut results = Vec::new();
        for workers in [1usize, 4, 8] {
            let mut dev = sim_dev();
            let mut eng = engine(&f, &plan, workers, shared(MeasurementCache::in_memory()), &mut dev);
            results.push(ga::optimize(len, &cfg, &mut eng));
        }
        for w in results.windows(2) {
            assert_eq!(w[0].best_gene, w[1].best_gene);
            assert_eq!(w[0].best_time, w[1].best_time);
            assert_eq!(w[0].evaluations, w[1].evaluations);
            assert_eq!(w[0].history.len(), w[1].history.len());
            for (a, b) in w[0].history.iter().zip(&w[1].history) {
                assert_eq!(a.best_time, b.best_time);
                assert_eq!(a.mean_time, b.mean_time);
                assert_eq!(a.evaluations, b.evaluations);
            }
        }
    }

    #[test]
    fn warm_cache_does_not_change_ga_history() {
        // memoization order / cache state must not affect selection
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let cfg = ga::GaConfig { population: 8, generations: 8, ..Default::default() };
        let cache = shared(MeasurementCache::in_memory());
        let mut d1 = sim_dev();
        let mut cold = engine(&f, &plan, 2, cache.clone(), &mut d1);
        let r_cold = ga::optimize(len, &cfg, &mut cold);
        let mut d2 = sim_dev();
        let mut warm = engine(&f, &plan, 2, cache, &mut d2);
        let r_warm = ga::optimize(len, &cfg, &mut warm);
        assert_eq!(warm.measured(), 0, "warm run must be all cache hits");
        assert_eq!(r_cold.best_gene, r_warm.best_gene);
        assert_eq!(r_cold.evaluations, r_warm.evaluations);
        for (a, b) in r_cold.history.iter().zip(&r_warm.history) {
            assert_eq!(a.best_time, b.best_time);
            assert_eq!(a.evaluations, b.evaluations);
        }
    }

    #[test]
    fn measure_full_returns_outcome_and_caches_time() {
        let f = fixture();
        let plan = |g: &[bool]| analysis::build_plan(&f.analysis, g, false);
        let len = f.analysis.gene_loops().len();
        let cache = shared(MeasurementCache::in_memory());
        let mut dev = sim_dev();
        let mut eng = engine(&f, &plan, 2, cache, &mut dev);
        let gene = vec![true; len];
        let m = eng.measure_full(&gene);
        assert!(m.ok, "{:?}", m.failure);
        assert!(m.outcome.is_some());
        // the scalar path now hits the cache
        let t = eng.measure_one(&gene);
        assert_eq!(t, m.ga_time());
        assert_eq!(eng.cache_hits(), 1);
    }

    #[test]
    fn compiled_cache_compiles_once_per_program() {
        let f = fixture();
        let mut cache = CompiledCache::new();
        let first = cache.get_or_compile(&f.prog).expect("fixture must compile");
        let again = cache.get_or_compile(&f.prog).expect("fixture must compile");
        assert!(Arc::ptr_eq(&first, &again), "second lookup must reuse the artifact");
        assert_eq!(cache.compiles(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        // a different program is a different entry, not a collision
        let other = parse("void main() { int a = 1; printf(\"%d\\n\", a); }", Lang::C, "other")
            .unwrap();
        cache.get_or_compile(&other).expect("trivial program must compile");
        assert_eq!(cache.compiles(), 2);
        assert_eq!(cache.len(), 2);
        assert_ne!(program_hash(&f.prog), program_hash(&other));
    }
}
