//! Routing policy for the sharded serve cluster (`envadapt route`):
//! rendezvous placement, shard health bookkeeping, sticky assignment
//! and the load-spill decision.
//!
//! This is a pure state machine — no sockets, no clocks — so every
//! policy rule is unit-testable in isolation; [`crate::router`] drives
//! it from the wire. The rules:
//!
//! * **Placement** — a request's route key (the engine fingerprint of
//!   its program) picks a *home* shard by rendezvous (highest-random-
//!   weight) hashing over the healthy shards: every router instance
//!   agrees on the mapping without coordination, and losing a shard
//!   remaps only the keys that lived on it.
//! * **Stickiness** — the first placement of a key is remembered and
//!   reused while that shard stays healthy. Replay correctness depends
//!   on this: the shard that learned a pattern replays it with zero
//!   measurements, so a key must not wander between shards faster than
//!   anti-entropy replication spreads its record.
//! * **Spill** — when the home shard looks overloaded (it answered
//!   `busy` since the last metrics poll, or its queue depth plus the
//!   router's own in-flight count reaches the spill threshold), *new*
//!   keys are placed on the least-loaded healthy shard instead. Spill
//!   is purely a routing decision — any shard can serve any request —
//!   so it trades replay locality for latency, never correctness.
//! * **Health** — [`DOWN_AFTER`] consecutive probe/request failures
//!   take a shard out of the rendezvous set; one success brings it
//!   back. Sticky entries pointing at a down shard re-home lazily on
//!   their next request.

use crate::util::fxhash::FxHasher;
use std::collections::HashMap;
use std::hash::Hasher;

/// Consecutive failures (health probes or forwarded requests) before a
/// shard is marked [`Health::Down`] and leaves the rendezvous set.
pub const DOWN_AFTER: u32 = 3;

/// Spill threshold when [`Fleet::new`] is given 0: a home shard whose
/// observed queue depth plus router-attributed in-flight requests
/// reaches this (or that answered `busy` since the last poll) sheds
/// new keys to the least-loaded healthy sibling.
pub const DEFAULT_SPILL_QUEUE: usize = 8;

/// A shard is either in the rendezvous set or not — there is no
/// half-in state; suspicion is the failure streak below [`DOWN_AFTER`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Up,
    Down,
}

/// Everything the router knows about one backend daemon.
#[derive(Debug, Clone)]
pub struct ShardState {
    /// backend address, exactly as given on the command line; doubles
    /// as the shard's rendezvous identity, so the mapping survives
    /// router restarts
    pub addr: String,
    pub health: Health,
    /// consecutive failures since the last success
    failures: u32,
    /// queue depth reported by the shard's last `metrics` poll
    pub queue_depth: usize,
    /// `busy` responses the shard shed between the last two polls
    pub busy_delta: u64,
    /// absolute `responses.busy` counter at the last poll
    busy_total: u64,
    /// offloads the router has forwarded here and not yet seen answered
    pub inflight: usize,
}

impl ShardState {
    fn new(addr: &str) -> ShardState {
        ShardState {
            addr: addr.to_string(),
            health: Health::Up,
            failures: 0,
            queue_depth: 0,
            busy_delta: 0,
            busy_total: 0,
            inflight: 0,
        }
    }

    /// The load signal spill decisions compare: what the shard reported
    /// queued, plus what the router has sent it since that report.
    pub fn load(&self) -> usize {
        self.queue_depth + self.inflight
    }

    /// Fold in one `metrics` poll: the shard's current queue depth and
    /// its absolute `responses.busy` counter (the delta against the
    /// previous poll is the freshest overload signal there is — the
    /// shard itself told a client to back off).
    pub fn note_poll(&mut self, queue_depth: usize, busy_total: u64) {
        self.busy_delta = busy_total.saturating_sub(self.busy_total);
        self.busy_total = busy_total;
        self.queue_depth = queue_depth;
    }

    /// Should new keys spill away from this shard?
    pub fn overloaded(&self, spill_queue: usize) -> bool {
        self.busy_delta > 0 || self.load() >= spill_queue
    }
}

/// Where [`Fleet::route`] decided one request goes, and why — the
/// router counts `spilled` routes per shard in its metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub shard: usize,
    /// placed off its rendezvous home because the home was overloaded
    pub spilled: bool,
    /// reused a remembered placement rather than computing one
    pub sticky: bool,
}

/// The cluster as the router sees it: shard states plus the sticky
/// key→shard table.
#[derive(Debug)]
pub struct Fleet {
    shards: Vec<ShardState>,
    sticky: HashMap<u64, usize>,
    spill_queue: usize,
}

impl Fleet {
    /// Build from backend addresses (order defines shard indices);
    /// `spill_queue` 0 takes [`DEFAULT_SPILL_QUEUE`]. Everything starts
    /// `Up` — the first health probe corrects optimism within a tick.
    pub fn new<S: AsRef<str>>(addrs: &[S], spill_queue: usize) -> Fleet {
        Fleet {
            shards: addrs.iter().map(|a| ShardState::new(a.as_ref())).collect(),
            sticky: HashMap::new(),
            spill_queue: if spill_queue == 0 { DEFAULT_SPILL_QUEUE } else { spill_queue },
        }
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn shard(&self, i: usize) -> &ShardState {
        &self.shards[i]
    }

    pub fn shard_mut(&mut self, i: usize) -> &mut ShardState {
        &mut self.shards[i]
    }

    pub fn healthy_count(&self) -> usize {
        self.shards.iter().filter(|s| s.health == Health::Up).count()
    }

    /// A probe or forwarded request succeeded. Returns `true` on a
    /// `Down → Up` transition (the router logs and counts these).
    pub fn note_success(&mut self, i: usize) -> bool {
        let s = &mut self.shards[i];
        s.failures = 0;
        if s.health == Health::Down {
            s.health = Health::Up;
            return true;
        }
        false
    }

    /// A probe or forwarded request failed. Returns `true` on an
    /// `Up → Down` transition ([`DOWN_AFTER`] consecutive failures).
    pub fn note_failure(&mut self, i: usize) -> bool {
        let s = &mut self.shards[i];
        s.failures = s.failures.saturating_add(1);
        if s.health == Health::Up && s.failures >= DOWN_AFTER {
            s.health = Health::Down;
            return true;
        }
        false
    }

    /// Rendezvous score of `key` on the shard named `addr`: both sides
    /// of the pair feed one hash, so each (key, shard) pair gets an
    /// independent uniform weight and the argmax is the HRW placement.
    fn score(key: u64, addr: &str) -> u64 {
        let mut h = FxHasher::default();
        h.write_u64(key);
        h.write(addr.as_bytes());
        h.finish()
    }

    /// The rendezvous home of `key` over the currently-healthy shards;
    /// `None` when every shard is down (the router answers
    /// `unavailable`).
    pub fn home(&self, key: u64) -> Option<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.health == Health::Up)
            .max_by_key(|(i, s)| (Self::score(key, &s.addr), usize::MAX - i))
            .map(|(i, _)| i)
    }

    /// Place one request: sticky placement if its shard is still
    /// healthy, otherwise the rendezvous home — unless the home is
    /// overloaded and a strictly less-loaded healthy sibling exists, in
    /// which case the key spills there. The chosen shard is remembered.
    pub fn route(&mut self, key: u64) -> Option<Route> {
        if let Some(&i) = self.sticky.get(&key) {
            if self.shards[i].health == Health::Up {
                return Some(Route { shard: i, spilled: false, sticky: true });
            }
        }
        let home = self.home(key)?;
        let mut chosen = home;
        let mut spilled = false;
        if self.shards[home].overloaded(self.spill_queue) {
            let alt = self
                .shards
                .iter()
                .enumerate()
                .filter(|(i, s)| *i != home && s.health == Health::Up)
                .min_by_key(|(_, s)| s.load())
                .map(|(i, _)| i);
            if let Some(alt) = alt {
                if self.shards[alt].load() < self.shards[home].load() {
                    chosen = alt;
                    spilled = true;
                }
            }
        }
        self.sticky.insert(key, chosen);
        Some(Route { shard: chosen, spilled, sticky: false })
    }

    /// Best healthy shard for `key` other than `exclude` — where a
    /// failed forward retries. `None` when no other shard is healthy.
    pub fn sibling(&self, key: u64, exclude: usize) -> Option<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(i, s)| *i != exclude && s.health == Health::Up)
            .max_by_key(|(i, s)| (Self::score(key, &s.addr), usize::MAX - i))
            .map(|(i, _)| i)
    }

    /// A retry landed `key` somewhere other than its recorded
    /// placement: remember the shard that actually answered.
    pub fn resticky(&mut self, key: u64, shard: usize) {
        self.sticky.insert(key, shard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> Fleet {
        let addrs: Vec<String> = (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect();
        Fleet::new(&addrs, 0)
    }

    fn down(f: &mut Fleet, i: usize) {
        for _ in 0..DOWN_AFTER {
            f.note_failure(i);
        }
        assert_eq!(f.shard(i).health, Health::Down);
    }

    #[test]
    fn rendezvous_is_deterministic_balanced_and_minimally_disruptive() {
        let mut f = fleet(3);
        let homes: Vec<usize> = (0..200).map(|k| f.home(k).unwrap()).collect();
        // deterministic
        for (k, &h) in homes.iter().enumerate() {
            assert_eq!(f.home(k as u64), Some(h));
        }
        // every shard owns a share of the keyspace
        for i in 0..3 {
            let n = homes.iter().filter(|&&h| h == i).count();
            assert!(n > 10, "shard {i} owns only {n}/200 keys");
        }
        // losing shard 1 moves only shard 1's keys
        down(&mut f, 1);
        for (k, &h) in homes.iter().enumerate() {
            let now = f.home(k as u64).unwrap();
            if h != 1 {
                assert_eq!(now, h, "key {k} moved off a healthy shard");
            } else {
                assert_ne!(now, 1, "key {k} still maps to the down shard");
            }
        }
        // recovery restores the original mapping exactly
        f.note_success(1);
        for (k, &h) in homes.iter().enumerate() {
            assert_eq!(f.home(k as u64), Some(h));
        }
    }

    #[test]
    fn health_transitions_need_a_streak_and_report_once() {
        let mut f = fleet(2);
        // a streak below the threshold, broken by one success: still up
        f.note_failure(0);
        f.note_failure(0);
        assert!(!f.note_success(0), "Up → Up is not a transition");
        assert_eq!(f.shard(0).health, Health::Up);
        // the full streak downs it, exactly once
        assert!(!f.note_failure(0));
        assert!(!f.note_failure(0));
        assert!(f.note_failure(0), "third consecutive failure transitions");
        assert!(!f.note_failure(0), "already down: no repeat transition");
        assert_eq!(f.healthy_count(), 1);
        // one success is enough to rejoin
        assert!(f.note_success(0));
        assert_eq!(f.shard(0).health, Health::Up);
    }

    #[test]
    fn routes_are_sticky_and_rehome_off_a_dead_shard() {
        let mut f = fleet(3);
        let key = 42;
        let first = f.route(key).unwrap();
        assert!(!first.sticky);
        assert_eq!(f.home(key), Some(first.shard), "unloaded route is the home");
        let again = f.route(key).unwrap();
        assert_eq!(again.shard, first.shard);
        assert!(again.sticky, "second placement reuses the first");
        // the shard dies: the key lazily re-homes and sticks there
        down(&mut f, first.shard);
        let moved = f.route(key).unwrap();
        assert_ne!(moved.shard, first.shard);
        assert!(!moved.sticky);
        assert!(f.route(key).unwrap().sticky);
    }

    #[test]
    fn overloaded_home_spills_new_keys_but_not_sticky_ones() {
        let mut f = fleet(3);
        // pick a key and pin it to its home before any overload
        let pinned = (0..).find(|&k| f.home(k) == Some(0)).unwrap();
        assert_eq!(f.route(pinned).unwrap().shard, 0);
        // shard 0 shed a busy since the last poll: overloaded
        f.shard_mut(0).note_poll(0, 1);
        assert!(f.shard(0).overloaded(DEFAULT_SPILL_QUEUE));
        f.shard_mut(0).inflight = 2; // spill target must be strictly lighter
        let fresh = (pinned + 1..).find(|&k| f.home(k) == Some(0)).unwrap();
        let spilled = f.route(fresh).unwrap();
        assert!(spilled.spilled, "new key on an overloaded home spills");
        assert_ne!(spilled.shard, 0);
        // the pinned key stays home: spill never moves an existing placement
        let r = f.route(pinned).unwrap();
        assert_eq!((r.shard, r.sticky), (0, true));
        // once the next poll clears the busy delta and load, new keys home again
        f.shard_mut(0).note_poll(0, 1);
        f.shard_mut(0).inflight = 0;
        assert!(!f.shard(0).overloaded(DEFAULT_SPILL_QUEUE));
        let later = (fresh + 1..).find(|&k| f.home(k) == Some(0)).unwrap();
        let r = f.route(later).unwrap();
        assert_eq!((r.shard, r.spilled), (0, false));
        // but the spilled key keeps its placement (replay locality)
        assert_eq!(f.route(fresh).unwrap().shard, spilled.shard);
    }

    #[test]
    fn spill_stays_home_when_every_sibling_is_as_loaded() {
        let mut f = fleet(2);
        f.shard_mut(0).note_poll(4, 1);
        f.shard_mut(1).note_poll(4, 0);
        let key = (0..).find(|&k| f.home(k) == Some(0)).unwrap();
        let r = f.route(key).unwrap();
        assert_eq!((r.shard, r.spilled), (0, false), "equal load: no point spilling");
    }

    #[test]
    fn sibling_skips_the_excluded_and_the_dead() {
        let mut f = fleet(3);
        let key = 7;
        let home = f.home(key).unwrap();
        let sib = f.sibling(key, home).unwrap();
        assert_ne!(sib, home);
        down(&mut f, sib);
        let next = f.sibling(key, home).unwrap();
        assert!(next != home && next != sib);
        down(&mut f, next);
        assert_eq!(f.sibling(key, home), None, "no healthy sibling left");
        // resticky records where a retry actually landed
        f.route(key);
        f.resticky(key, home);
        assert_eq!(f.route(key).unwrap().shard, home);
    }

    #[test]
    fn all_shards_down_routes_nowhere() {
        let mut f = fleet(2);
        down(&mut f, 0);
        down(&mut f, 1);
        assert_eq!(f.home(1), None);
        assert_eq!(f.route(1), None);
        assert_eq!(f.healthy_count(), 0);
    }
}
