//! Language-independent intermediate representation.
//!
//! The paper's common method hinges on managing "loops, variables and
//! function blocks" abstractly, independent of the source language
//! (§3.3: ループと変数の把握については…言語に非依存に抽象的に管理できる).
//! Every front end (C, Python, Java, JavaScript) lowers to this IR; the
//! analysis, GA, clone-detection and execution layers never see language
//! syntax again.

use std::fmt;

/// Source language of a program (kept for reporting and directive rendering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lang {
    C,
    Python,
    Java,
    JavaScript,
}

impl Lang {
    pub fn name(&self) -> &'static str {
        match self {
            Lang::C => "c",
            Lang::Python => "python",
            Lang::Java => "java",
            Lang::JavaScript => "javascript",
        }
    }

    /// Parse a language name (the inverse of [`Lang::name`]; used by the
    /// CLI, the service protocol and pattern-DB persistence).
    pub fn from_name(name: &str) -> Option<Lang> {
        match name {
            "c" => Some(Lang::C),
            "python" | "py" => Some(Lang::Python),
            "java" => Some(Lang::Java),
            "javascript" | "js" => Some(Lang::JavaScript),
            _ => None,
        }
    }

    /// Guess a language from a file extension.
    pub fn from_ext(ext: &str) -> Option<Lang> {
        match ext {
            "c" | "h" | "cc" | "cpp" => Some(Lang::C),
            "py" => Some(Lang::Python),
            "java" => Some(Lang::Java),
            "js" | "mjs" => Some(Lang::JavaScript),
            _ => None,
        }
    }

    pub fn all() -> [Lang; 4] {
        [Lang::C, Lang::Python, Lang::Java, Lang::JavaScript]
    }
}

impl fmt::Display for Lang {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Scalar / array types. Front ends map `int`/`long` → `Int`,
/// `float`/`double` → `Float`. Arrays are row-major f64 buffers with a
/// static rank; extents are expressions evaluated at declaration time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Type {
    Int,
    Float,
    /// element type + rank (number of dimensions).
    Array { elem: Box<Type>, rank: usize },
    Void,
}

impl Type {
    pub fn array_of(elem: Type, rank: usize) -> Type {
        Type::Array { elem: Box::new(elem), rank }
    }
    pub fn is_array(&self) -> bool {
        matches!(self, Type::Array { .. })
    }
}

/// Binary operators (normalized across languages).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    And,
    Or,
}

impl BinOp {
    pub fn is_cmp(&self) -> bool {
        matches!(self, BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne)
    }
    pub fn sym(&self) -> &'static str {
        use BinOp::*;
        match self {
            Add => "+",
            Sub => "-",
            Mul => "*",
            Div => "/",
            Mod => "%",
            Lt => "<",
            Le => "<=",
            Gt => ">",
            Ge => ">=",
            Eq => "==",
            Ne => "!=",
            And => "&&",
            Or => "||",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Not,
}

/// Math intrinsics available in all four source languages
/// (`math.h`, `import math`, `java.lang.Math`, JavaScript's `Math`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Intrinsic {
    Sqrt,
    Exp,
    Log,
    Sin,
    Cos,
    Fabs,
    Pow,
    Min,
    Max,
    Floor,
}

impl Intrinsic {
    pub fn from_name(name: &str) -> Option<Intrinsic> {
        Some(match name {
            "sqrt" => Intrinsic::Sqrt,
            "exp" => Intrinsic::Exp,
            "log" => Intrinsic::Log,
            "sin" => Intrinsic::Sin,
            "cos" => Intrinsic::Cos,
            "fabs" | "abs" | "fabsf" => Intrinsic::Fabs,
            "pow" => Intrinsic::Pow,
            "min" | "fmin" => Intrinsic::Min,
            "max" | "fmax" => Intrinsic::Max,
            "floor" => Intrinsic::Floor,
            _ => return None,
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Intrinsic::Sqrt => "sqrt",
            Intrinsic::Exp => "exp",
            Intrinsic::Log => "log",
            Intrinsic::Sin => "sin",
            Intrinsic::Cos => "cos",
            Intrinsic::Fabs => "fabs",
            Intrinsic::Pow => "pow",
            Intrinsic::Min => "min",
            Intrinsic::Max => "max",
            Intrinsic::Floor => "floor",
        }
    }
    pub fn arity(&self) -> usize {
        match self {
            Intrinsic::Pow | Intrinsic::Min | Intrinsic::Max => 2,
            _ => 1,
        }
    }
}

/// Expressions. Variable references are by name; the VM resolves names to
/// slots once per function (see `vm`).
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    IntLit(i64),
    FloatLit(f64),
    Var(String),
    /// `a[i]`, `a[i][j]`, ... — row-major index into an array variable.
    Index { base: String, indices: Vec<Expr> },
    Binary { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr> },
    Unary { op: UnOp, operand: Box<Expr> },
    Intrinsic { f: Intrinsic, args: Vec<Expr> },
    /// User-function or library call in expression position.
    Call { name: String, args: Vec<Expr> },
    /// `len(a, dim)` — array extent along a dimension.
    Len { base: String, dim: usize },
}

impl Expr {
    pub fn int(v: i64) -> Expr {
        Expr::IntLit(v)
    }
    pub fn var(n: &str) -> Expr {
        Expr::Var(n.to_string())
    }
    pub fn bin(op: BinOp, l: Expr, r: Expr) -> Expr {
        Expr::Binary { op, lhs: Box::new(l), rhs: Box::new(r) }
    }

    /// Collect every variable name referenced by this expression.
    pub fn collect_vars(&self, out: &mut Vec<String>) {
        match self {
            Expr::IntLit(_) | Expr::FloatLit(_) => {}
            Expr::Var(n) => out.push(n.clone()),
            Expr::Index { base, indices } => {
                out.push(base.clone());
                for i in indices {
                    i.collect_vars(out);
                }
            }
            Expr::Binary { lhs, rhs, .. } => {
                lhs.collect_vars(out);
                rhs.collect_vars(out);
            }
            Expr::Unary { operand, .. } => operand.collect_vars(out),
            Expr::Intrinsic { args, .. } | Expr::Call { args, .. } => {
                for a in args {
                    a.collect_vars(out);
                }
            }
            Expr::Len { base, .. } => out.push(base.clone()),
        }
    }

    /// Collect names of user/library functions called within.
    pub fn collect_calls(&self, out: &mut Vec<String>) {
        match self {
            Expr::Call { name, args } => {
                out.push(name.clone());
                for a in args {
                    a.collect_calls(out);
                }
            }
            Expr::Binary { lhs, rhs, .. } => {
                lhs.collect_calls(out);
                rhs.collect_calls(out);
            }
            Expr::Unary { operand, .. } => operand.collect_calls(out),
            Expr::Intrinsic { args, .. } => {
                for a in args {
                    a.collect_calls(out);
                }
            }
            Expr::Index { indices, .. } => {
                for i in indices {
                    i.collect_calls(out);
                }
            }
            _ => {}
        }
    }
}

/// Assignment targets.
#[derive(Debug, Clone, PartialEq)]
pub enum LValue {
    Var(String),
    Index { base: String, indices: Vec<Expr> },
}

impl LValue {
    pub fn base_name(&self) -> &str {
        match self {
            LValue::Var(n) => n,
            LValue::Index { base, .. } => base,
        }
    }
}

/// Compound-assignment operators (`x += e` etc.). `Set` is plain `=`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignOp {
    Set,
    Add,
    Sub,
    Mul,
    Div,
}

/// Stable identifier of a `for` loop within a program. Assigned in
/// pre-order over all functions by `Program::number_loops`; the GA gene
/// ("loop i offloaded?") indexes the *parallelizable subset* of these.
pub type LoopId = usize;

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// Variable declaration. For arrays, `dims` holds one extent expression
    /// per dimension; `init` is an optional scalar initializer.
    Decl { name: String, ty: Type, dims: Vec<Expr>, init: Option<Expr> },
    Assign { target: LValue, op: AssignOp, value: Expr },
    /// Counted loop `for v in [start, end) step step`. The only loop form
    /// eligible for offload (the paper targets `for` statements).
    For {
        id: LoopId,
        var: String,
        start: Expr,
        end: Expr,
        step: Expr,
        body: Vec<Stmt>,
    },
    While { cond: Expr, body: Vec<Stmt> },
    If { cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt> },
    /// Call in statement position (library calls live here:
    /// `matmul(a,b,c,n)`).
    Call { name: String, args: Vec<Expr> },
    Return(Option<Expr>),
    Break,
    Continue,
    /// `print(expr)` — output captured by the VM, used for result checks.
    Print(Expr),
}

/// A function definition.
#[derive(Debug, Clone, PartialEq)]
pub struct Function {
    pub name: String,
    pub params: Vec<Param>,
    pub ret: Type,
    pub body: Vec<Stmt>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub ty: Type,
}

/// A whole translation unit in the language-independent IR.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub lang: Lang,
    pub name: String,
    pub functions: Vec<Function>,
}

impl Program {
    /// Entry function: `main` and its Python/Java equivalents are all
    /// normalized to the IR name `main` by the front ends.
    pub fn entry(&self) -> Option<&Function> {
        self.functions.iter().find(|f| f.name == "main")
    }

    pub fn function(&self, name: &str) -> Option<&Function> {
        self.functions.iter().find(|f| f.name == name)
    }

    /// Re-number every `For` loop in pre-order across all functions so that
    /// `LoopId`s are dense and stable. Front ends call this after parsing.
    pub fn number_loops(&mut self) -> usize {
        let mut next = 0usize;
        for f in &mut self.functions {
            number_block(&mut f.body, &mut next);
        }
        next
    }

    /// Total number of `For` loops.
    pub fn loop_count(&self) -> usize {
        let mut n = 0;
        for f in &self.functions {
            count_block(&f.body, &mut n);
        }
        n
    }

    /// Visit every statement (pre-order), with the id of the innermost
    /// enclosing `For` loop (if any).
    pub fn visit_stmts<'a>(&'a self, mut f: impl FnMut(&'a Stmt, Option<LoopId>)) {
        fn walk<'a>(
            body: &'a [Stmt],
            encl: Option<LoopId>,
            f: &mut impl FnMut(&'a Stmt, Option<LoopId>),
        ) {
            for s in body {
                f(s, encl);
                match s {
                    Stmt::For { id, body, .. } => walk(body, Some(*id), f),
                    Stmt::While { body, .. } => walk(body, encl, f),
                    Stmt::If { then_body, else_body, .. } => {
                        walk(then_body, encl, f);
                        walk(else_body, encl, f);
                    }
                    _ => {}
                }
            }
        }
        for func in &self.functions {
            walk(&func.body, None, &mut f);
        }
    }
}

impl Program {
    /// Find the `For` statement with the given loop id.
    pub fn find_for(&self, id: LoopId) -> Option<&Stmt> {
        fn walk(body: &[Stmt], id: LoopId) -> Option<&Stmt> {
            for s in body {
                match s {
                    Stmt::For { id: i, body: inner, .. } => {
                        if *i == id {
                            return Some(s);
                        }
                        if let Some(f) = walk(inner, id) {
                            return Some(f);
                        }
                    }
                    Stmt::While { body, .. } => {
                        if let Some(f) = walk(body, id) {
                            return Some(f);
                        }
                    }
                    Stmt::If { then_body, else_body, .. } => {
                        if let Some(f) = walk(then_body, id) {
                            return Some(f);
                        }
                        if let Some(f) = walk(else_body, id) {
                            return Some(f);
                        }
                    }
                    _ => {}
                }
            }
            None
        }
        for f in &self.functions {
            if let Some(s) = walk(&f.body, id) {
                return Some(s);
            }
        }
        None
    }
}

impl Program {
    /// Rewrite every expression in the program bottom-up with `f`.
    /// Used by the front-end post-pass that turns `Call("sqrt", ..)` into
    /// `Intrinsic(Sqrt, ..)` when no user function shadows the name.
    pub fn rewrite_exprs(&mut self, f: &mut impl FnMut(&mut Expr)) {
        for func in &mut self.functions {
            rewrite_block(&mut func.body, f);
        }
    }
}

fn rewrite_block(body: &mut [Stmt], f: &mut impl FnMut(&mut Expr)) {
    for s in body {
        match s {
            Stmt::Decl { dims, init, .. } => {
                for d in dims {
                    rewrite_expr(d, f);
                }
                if let Some(e) = init {
                    rewrite_expr(e, f);
                }
            }
            Stmt::Assign { target, value, .. } => {
                if let LValue::Index { indices, .. } = target {
                    for i in indices {
                        rewrite_expr(i, f);
                    }
                }
                rewrite_expr(value, f);
            }
            Stmt::For { start, end, step, body, .. } => {
                rewrite_expr(start, f);
                rewrite_expr(end, f);
                rewrite_expr(step, f);
                rewrite_block(body, f);
            }
            Stmt::While { cond, body } => {
                rewrite_expr(cond, f);
                rewrite_block(body, f);
            }
            Stmt::If { cond, then_body, else_body } => {
                rewrite_expr(cond, f);
                rewrite_block(then_body, f);
                rewrite_block(else_body, f);
            }
            Stmt::Call { args, .. } => {
                for a in args {
                    rewrite_expr(a, f);
                }
            }
            Stmt::Return(Some(e)) | Stmt::Print(e) => rewrite_expr(e, f),
            Stmt::Return(None) | Stmt::Break | Stmt::Continue => {}
        }
    }
}

fn rewrite_expr(e: &mut Expr, f: &mut impl FnMut(&mut Expr)) {
    match e {
        Expr::Index { indices, .. } => {
            for i in indices {
                rewrite_expr(i, f);
            }
        }
        Expr::Binary { lhs, rhs, .. } => {
            rewrite_expr(lhs, f);
            rewrite_expr(rhs, f);
        }
        Expr::Unary { operand, .. } => rewrite_expr(operand, f),
        Expr::Intrinsic { args, .. } | Expr::Call { args, .. } => {
            for a in args {
                rewrite_expr(a, f);
            }
        }
        _ => {}
    }
    f(e);
}

fn number_block(body: &mut [Stmt], next: &mut usize) {
    for s in body {
        match s {
            Stmt::For { id, body, .. } => {
                *id = *next;
                *next += 1;
                number_block(body, next);
            }
            Stmt::While { body, .. } => number_block(body, next),
            Stmt::If { then_body, else_body, .. } => {
                number_block(then_body, next);
                number_block(else_body, next);
            }
            _ => {}
        }
    }
}

fn count_block(body: &[Stmt], n: &mut usize) {
    for s in body {
        match s {
            Stmt::For { body, .. } => {
                *n += 1;
                count_block(body, n);
            }
            Stmt::While { body, .. } => count_block(body, n),
            Stmt::If { then_body, else_body, .. } => {
                count_block(then_body, n);
                count_block(else_body, n);
            }
            _ => {}
        }
    }
}

/// Node kinds used by the Deckard-style clone detector (`clone`): a fixed,
/// language-independent alphabet over which characteristic vectors are
/// computed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum NodeKind {
    For = 0,
    While,
    If,
    Assign,
    CompoundAssign,
    Decl,
    CallStmt,
    Return,
    Print,
    BreakContinue,
    BinAdd,
    BinSub,
    BinMul,
    BinDiv,
    BinMod,
    BinCmp,
    BinLogic,
    Unary,
    IndexRead,
    VarRead,
    Literal,
    IntrinsicSqrt,
    IntrinsicExpLog,
    IntrinsicTrig,
    IntrinsicOther,
    CallExpr,
    Len,
    IndexWrite,
    ScalarWrite,
    Reduction,
}

pub const NODE_KIND_COUNT: usize = NodeKind::Reduction as usize + 1;

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_loop(id: LoopId, body: Vec<Stmt>) -> Stmt {
        Stmt::For {
            id,
            var: "i".into(),
            start: Expr::int(0),
            end: Expr::var("n"),
            step: Expr::int(1),
            body,
        }
    }

    #[test]
    fn loop_numbering_is_preorder_and_dense() {
        let mut p = Program {
            lang: Lang::C,
            name: "t".into(),
            functions: vec![Function {
                name: "main".into(),
                params: vec![],
                ret: Type::Void,
                body: vec![
                    sample_loop(99, vec![sample_loop(99, vec![])]),
                    sample_loop(99, vec![]),
                ],
            }],
        };
        let n = p.number_loops();
        assert_eq!(n, 3);
        let mut ids = vec![];
        p.visit_stmts(|s, _| {
            if let Stmt::For { id, .. } = s {
                ids.push(*id);
            }
        });
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(p.loop_count(), 3);
    }

    #[test]
    fn visit_reports_enclosing_loop() {
        let mut p = Program {
            lang: Lang::Python,
            name: "t".into(),
            functions: vec![Function {
                name: "main".into(),
                params: vec![],
                ret: Type::Void,
                body: vec![sample_loop(
                    0,
                    vec![Stmt::Assign {
                        target: LValue::Var("x".into()),
                        op: AssignOp::Add,
                        value: Expr::int(1),
                    }],
                )],
            }],
        };
        p.number_loops();
        let mut seen = None;
        p.visit_stmts(|s, encl| {
            if matches!(s, Stmt::Assign { .. }) {
                seen = Some(encl);
            }
        });
        assert_eq!(seen, Some(Some(0)));
    }

    #[test]
    fn expr_var_and_call_collection() {
        let e = Expr::Binary {
            op: BinOp::Add,
            lhs: Box::new(Expr::Index {
                base: "a".into(),
                indices: vec![Expr::var("i")],
            }),
            rhs: Box::new(Expr::Call { name: "f".into(), args: vec![Expr::var("x")] }),
        };
        let mut vars = vec![];
        e.collect_vars(&mut vars);
        assert_eq!(vars, vec!["a", "i", "x"]);
        let mut calls = vec![];
        e.collect_calls(&mut calls);
        assert_eq!(calls, vec!["f"]);
    }

    #[test]
    fn intrinsic_round_trip() {
        for n in ["sqrt", "exp", "log", "sin", "cos", "fabs", "pow", "min", "max", "floor"] {
            let i = Intrinsic::from_name(n).unwrap();
            assert_eq!(Intrinsic::from_name(i.name()), Some(i));
        }
        assert!(Intrinsic::from_name("nope").is_none());
    }

    #[test]
    fn lang_from_ext() {
        assert_eq!(Lang::from_ext("c"), Some(Lang::C));
        assert_eq!(Lang::from_ext("py"), Some(Lang::Python));
        assert_eq!(Lang::from_ext("java"), Some(Lang::Java));
        assert_eq!(Lang::from_ext("js"), Some(Lang::JavaScript));
        assert_eq!(Lang::from_ext("mjs"), Some(Lang::JavaScript));
        assert_eq!(Lang::from_ext("rs"), None);
    }

    #[test]
    fn lang_names_round_trip() {
        for lang in Lang::all() {
            assert_eq!(Lang::from_name(lang.name()), Some(lang));
        }
        assert_eq!(Lang::from_name("js"), Some(Lang::JavaScript));
        assert!(Lang::from_name("cobol").is_none());
    }
}
