//! The offload service (`envadapt serve`): the long-lived, multi-tenant
//! daemon the paper's commercial flow describes — user code in any
//! supported language arrives as a request, is converted and verified,
//! and every verified pattern is remembered so the next matching request
//! skips the search entirely.
//!
//! Architecture (see `DESIGN.md` §6/§9):
//!
//! * **Transport** — line-delimited JSON ([`crate::proto`], wire v2 with
//!   v1 compat) over TCP (`serve_tcp`, one thread per connection) or
//!   stdin/stdout (`serve_stdio`). Connections only frame and route;
//!   they never touch a device.
//! * **Worker pool** — [`Service::start`] spawns `pool` OS threads, each
//!   owning an [`OffloadSession`] (devices are not `Send`, so sessions
//!   are built inside their worker thread; each lazily keeps one
//!   coordinator per request variant). Workers pull `Job`s from one
//!   shared queue; replies go back over per-request channels, so slow
//!   searches never block other connections. The per-session
//!   measurement-worker budget is `cfg.workers / pool`; the CLI rejects
//!   an explicitly oversubscribed `--pool × --workers` split up front
//!   via [`crate::api::validate_worker_split`] (embedders passing their
//!   own `ServeOptions` should call it too), and an auto-sized pool
//!   (`pool: 0`) is clamped to the budget so it never starves a session.
//! * **Shared learning state** — all worker sessions share one
//!   measurement cache ([`crate::engine::SharedCache`]) and one pattern
//!   DB ([`SharedPatternDb`]): a pattern learned by any worker is
//!   replayed by every worker, and persists across restarts via
//!   `ServeOptions::db_path`.

use crate::api::{OffloadRequest, OffloadSession};
use crate::config::Config;
use crate::engine::{self, SharedCache};
use crate::patterndb::{self, PatternDb, SharedPatternDb};
use crate::proto::{self, Op, Request};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

/// Service-level options (everything else comes from [`Config`]).
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// coordinator pool size; 0 = min(4, host parallelism), clamped to
    /// the measurement-worker budget so auto-sizing never starves a
    /// session
    pub pool: usize,
    /// pattern-DB persistence file: learned patterns are loaded at start
    /// and saved after every insert, so the service resumes warm
    pub db_path: Option<PathBuf>,
}

/// Cumulative request counters (one instance per service, shared).
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub offloads: u64,
    pub errors: u64,
    /// offloads answered from the learned pattern DB (zero-search replay)
    pub reuse_hits: u64,
    /// offloads that inserted a new learned pattern
    pub learned: u64,
    /// search measurements spent across all offloads
    pub measurements: u64,
}

struct Job {
    id: i64,
    req: OffloadRequest,
    warnings: Vec<String>,
    reply: Sender<Json>,
}

/// The shared service core: worker pool + job queue + learning state.
/// (`Sender` sits behind a `Mutex` so `Service` is `Sync` on every
/// supported toolchain; the lock covers only the enqueue, never the
/// search itself.)
pub struct Service {
    jobs: Mutex<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    db: SharedPatternDb,
    cache: SharedCache,
    stats: Arc<Mutex<ServiceStats>>,
    pool: usize,
    started: std::time::Instant,
}

impl Service {
    /// Build the shared state and spawn the session worker pool.
    ///
    /// An explicit `opts.pool` is honored as-is (the budget split
    /// bottoms out at one measurement worker per session): the
    /// measurement budget defaults to the *host's* parallelism, so
    /// hard-failing here would make a fixed `pool` value start or not
    /// start depending on the machine. Front ends that take both knobs
    /// from a user should reject an oversubscribed split up front via
    /// [`crate::api::validate_worker_split`], as the CLI does.
    pub fn start(cfg: Config, opts: &ServeOptions) -> Service {
        let budget = cfg.effective_workers();
        let pool = if opts.pool == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(4)
                .min(budget)
                .max(1)
        } else {
            opts.pool
        };
        let mut cfg = cfg;
        cfg.pattern_db_path = opts.db_path.clone();
        // split the measurement-worker budget across the pool so the two
        // pool levels don't multiply into pool × cfg.workers threads
        let mut wcfg = cfg.clone();
        wcfg.workers = (budget / pool).max(1);
        let db = patterndb::shared(PatternDb::open_or_builtin(opts.db_path.as_deref()));
        let cache = engine::cache_for(&cfg);
        let stats = Arc::new(Mutex::new(ServiceStats::default()));
        let (jobs, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(pool);
        for wid in 0..pool {
            let rx = rx.clone();
            let wcfg = wcfg.clone();
            let db = db.clone();
            let cache = cache.clone();
            let stats = stats.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(wid, wcfg, db, cache, rx, stats)
            }));
        }
        Service {
            jobs: Mutex::new(jobs),
            workers,
            db,
            cache,
            stats,
            pool,
            started: std::time::Instant::now(),
        }
    }

    /// Handle one request line; returns the response and whether the
    /// caller should shut the whole service down.
    pub fn dispatch_line(&self, line: &str) -> (Json, bool) {
        match Request::parse_line(line) {
            Ok(req) => self.dispatch(req),
            Err(e) => {
                let mut s = self.stats.lock().unwrap();
                s.requests += 1;
                s.errors += 1;
                // echo the id when the line was at least JSON, so
                // pipelining clients can still match the error
                (proto::err(proto::line_id(line), &e.to_string()), false)
            }
        }
    }

    /// Handle one parsed request.
    pub fn dispatch(&self, req: Request) -> (Json, bool) {
        self.stats.lock().unwrap().requests += 1;
        let Request { id, op, warnings } = req;
        match op {
            Op::Offload(r) => {
                let (tx, rx) = mpsc::channel();
                let enqueued =
                    self.jobs.lock().unwrap().send(Job { id, req: *r, warnings, reply: tx });
                if enqueued.is_err() {
                    self.stats.lock().unwrap().errors += 1;
                    return (proto::err(id, "service is shutting down"), false);
                }
                match rx.recv() {
                    Ok(resp) => (resp, false),
                    Err(_) => {
                        self.stats.lock().unwrap().errors += 1;
                        (proto::err(id, "worker died before replying"), false)
                    }
                }
            }
            Op::Stats => (proto::ok_stats(id, self.stats_json(), &warnings), false),
            Op::Ping => (proto::ok_simple(id, "ping", &warnings), false),
            Op::Shutdown => (proto::ok_simple(id, "shutdown", &warnings), true),
        }
    }

    /// The `stats` op payload: request counters plus the shared learning
    /// state (pattern DB size, measurement-cache traffic).
    pub fn stats_json(&self) -> Json {
        let (requests, offloads, errors, reuse_hits, learned, measurements) = {
            let s = self.stats.lock().unwrap();
            (s.requests, s.offloads, s.errors, s.reuse_hits, s.learned, s.measurements)
        };
        let (cache_entries, cache_hits, cache_misses) = {
            let c = self.cache.lock().unwrap();
            (c.len(), c.hit_count(), c.miss_count())
        };
        let learned_records = self.db.lock().unwrap().learned_len();
        Json::obj()
            .set("workers", self.pool)
            .set("uptime_s", self.started.elapsed().as_secs_f64())
            .set("requests", requests as i64)
            .set("offloads", offloads as i64)
            .set("errors", errors as i64)
            .set("pattern_reuse_hits", reuse_hits as i64)
            .set("patterns_learned", learned as i64)
            .set("learned_records", learned_records)
            .set("search_measurements", measurements as i64)
            .set("cache_entries", cache_entries)
            .set("cache_hits", cache_hits as i64)
            .set("cache_misses", cache_misses as i64)
    }

    /// Handle on the shared pattern DB (tests, introspection).
    pub fn db(&self) -> SharedPatternDb {
        self.db.clone()
    }

    /// Close the job queue and join the worker pool.
    pub fn shutdown(self) {
        drop(self.jobs);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    wid: usize,
    cfg: Config,
    db: SharedPatternDb,
    cache: SharedCache,
    rx: Arc<Mutex<Receiver<Job>>>,
    stats: Arc<Mutex<ServiceStats>>,
) {
    // Each worker owns one OffloadSession, built inside this thread
    // (devices are not Send) and living for the whole service, so PJRT
    // executable caches stay warm across requests. The session keeps one
    // coordinator per request variant; all sessions share the cache and
    // pattern DB handed in here.
    let mut session = OffloadSession::with_shared(cfg, cache, db);
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => break, // queue closed: service is shutting down
        };
        let resp = handle_offload(wid, &mut session, &job, &stats);
        // a dropped reply receiver just means the client went away
        let _ = job.reply.send(resp);
    }
}

fn handle_offload(
    wid: usize,
    session: &mut OffloadSession,
    job: &Job,
    stats: &Arc<Mutex<ServiceStats>>,
) -> Json {
    match session.offload(&job.req) {
        Ok(report) => {
            {
                let mut s = stats.lock().unwrap();
                s.offloads += 1;
                s.measurements += report.total_measurements as u64;
                if report.reused_pattern.is_some() {
                    s.reuse_hits += 1;
                }
                if report.learned_pattern {
                    s.learned += 1;
                }
            }
            proto::ok_offload(job.id, &report, wid, &job.warnings)
        }
        Err(e) => {
            stats.lock().unwrap().errors += 1;
            proto::err(job.id, &e.to_string())
        }
    }
}

// ---------------------------------------------------------------------------
// transports
// ---------------------------------------------------------------------------

/// Serve one client connection; returns whether the client requested
/// service shutdown.
fn handle_conn(stream: TcpStream, service: &Service) -> bool {
    let Ok(read_half) = stream.try_clone() else { return false };
    let reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = service.dispatch_line(&line);
        if writer.write_all(resp.to_string().as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
            || writer.flush().is_err()
        {
            break;
        }
        if quit {
            return true;
        }
    }
    false
}

/// Accept loop over an already-bound listener: one thread per connection,
/// all feeding the shared [`Service`]. Returns when a client sends the
/// `shutdown` op (after draining connections and joining the pool).
pub fn serve_listener(listener: TcpListener, cfg: Config, opts: ServeOptions) -> Result<()> {
    let service = Arc::new(Service::start(cfg, &opts));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = listener.local_addr()?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let service = service.clone();
        let stop = stop.clone();
        // reap finished connections so a long-lived daemon doesn't
        // accumulate one JoinHandle per client forever
        conns.retain(|c| !c.is_finished());
        conns.push(std::thread::spawn(move || {
            if handle_conn(stream, &service) {
                // shutdown requested: stop accepting, then wake the
                // accept loop with a throwaway connection
                stop.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(addr);
            }
        }));
    }
    for c in conns {
        let _ = c.join();
    }
    if let Ok(service) = Arc::try_unwrap(service) {
        service.shutdown();
    }
    Ok(())
}

/// Bind `addr` (e.g. `127.0.0.1:7777`; port 0 picks an ephemeral port)
/// and serve until a client sends `shutdown`. Blocking — this is what
/// `envadapt serve` runs.
pub fn serve_tcp(addr: &str, cfg: Config, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("envadapt serve: listening on {}", listener.local_addr()?);
    serve_listener(listener, cfg, opts)
}

/// Serve line-delimited JSON on stdin/stdout (single-client mode; offload
/// work still runs on the session pool). Returns at EOF or on the
/// `shutdown` op.
pub fn serve_stdio(cfg: Config, opts: ServeOptions) -> Result<()> {
    let service = Service::start(cfg, &opts);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = service.dispatch_line(&line);
        out.write_all(resp.to_string().as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
        if quit {
            break;
        }
    }
    service.shutdown();
    Ok(())
}

/// Handle on a server running on a background thread (tests, examples,
/// embedding).
pub struct ServerHandle {
    addr: SocketAddr,
    thread: JoinHandle<Result<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the server to stop (a `shutdown` request over a fresh
    /// connection) and wait for it to wind down. Graceful: open client
    /// connections are drained first, so disconnect clients before
    /// calling this for a prompt return.
    pub fn shutdown(self) -> Result<()> {
        let mut stream = TcpStream::connect(self.addr)?;
        stream.write_all(b"{\"op\":\"shutdown\",\"id\":0}\n")?;
        stream.flush()?;
        let mut line = String::new();
        let _ = BufReader::new(stream).read_line(&mut line);
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("server thread panicked")),
        }
    }
}

/// Bind `addr` and serve on a background thread; the returned handle
/// carries the bound address (bind port 0 for an ephemeral port).
pub fn spawn_tcp(cfg: Config, opts: ServeOptions, addr: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let thread = std::thread::spawn(move || serve_listener(listener, cfg, opts));
    Ok(ServerHandle { addr, thread })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::TargetKind;
    use crate::ir::Lang;

    fn service() -> Service {
        Service::start(Config::fast_sim(), &ServeOptions { pool: 2, db_path: None })
    }

    #[test]
    fn dispatch_ping_stats_and_errors() {
        let s = service();
        let (resp, quit) = s.dispatch_line(r#"{"op":"ping","id":5}"#);
        assert!(!quit);
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(5));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            resp.get("schema_version").and_then(|v| v.as_i64()),
            Some(crate::api::SCHEMA_VERSION),
            "every response is versioned: {}",
            resp.to_string()
        );

        let (resp, _) = s.dispatch_line("garbage");
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));

        let (resp, quit) = s.dispatch_line(r#"{"op":"stats","id":6}"#);
        assert!(!quit);
        let stats = resp.get("stats").expect("stats payload");
        assert_eq!(stats.get("requests").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(stats.get("errors").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(stats.get("workers").and_then(|v| v.as_i64()), Some(2));

        let (_, quit) = s.dispatch_line(r#"{"op":"shutdown","id":7}"#);
        assert!(quit);
        s.shutdown();
    }

    #[test]
    fn unknown_op_lists_supported_ops() {
        let s = service();
        let (resp, quit) = s.dispatch_line(r#"{"op":"dance","id":3}"#);
        assert!(!quit);
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(3));
        let err = resp.get("error").and_then(|v| v.as_str()).unwrap();
        assert!(
            err.contains("supported: offload, stats, ping, shutdown"),
            "unknown-op error must name the supported ops: {err}"
        );
        s.shutdown();
    }

    #[test]
    fn unknown_request_fields_surface_as_warnings() {
        let s = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let line = format!(
            r#"{{"op":"offload","id":4,"name":"smallloops","lang":"c","code":{},"tarmget":"gpu"}}"#,
            Json::Str(code.to_string()).to_string()
        );
        let (resp, _) = s.dispatch_line(&line);
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", resp.to_string());
        let warnings = resp.get("warnings").and_then(|v| v.items()).expect("warnings array");
        assert_eq!(warnings.len(), 1, "{}", resp.to_string());
        assert!(warnings[0].as_str().unwrap().contains("tarmget"));
        // well-formed requests carry no warnings array at all
        let (resp, _) = s.dispatch_line(r#"{"op":"ping","id":5}"#);
        assert!(resp.get("warnings").is_none());
        s.shutdown();
    }

    #[test]
    fn offload_learns_then_replays() {
        let s = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let line = proto::offload_request(1, "smallloops", Lang::C, code);
        let (r1, _) = s.dispatch_line(&line);
        assert_eq!(r1.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", r1.to_string());
        let rep1 = r1.get("report").unwrap();
        assert!(rep1.get("measurements").and_then(|v| v.as_i64()).unwrap() > 0);
        assert!(rep1.get("pattern_reuse").is_none());

        let (r2, _) = s.dispatch_line(&line);
        let rep2 = r2.get("report").unwrap();
        assert_eq!(rep2.get("measurements").and_then(|v| v.as_i64()), Some(0));
        assert!(rep2.get("pattern_reuse").is_some());
        assert_eq!(rep2.get("gene"), rep1.get("gene"));

        let (stats, _) = s.dispatch_line(r#"{"op":"stats","id":9}"#);
        let stats = stats.get("stats").unwrap();
        assert_eq!(stats.get("offloads").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(stats.get("pattern_reuse_hits").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(stats.get("patterns_learned").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(stats.get("learned_records").and_then(|v| v.as_i64()), Some(1));
        s.shutdown();
    }

    #[test]
    fn per_request_target_override() {
        let s = service();
        let code = crate::workloads::get("blackscholes", Lang::C).unwrap().code;
        let req = OffloadRequest::source(code, Lang::C)
            .name("blackscholes")
            .devices(vec![TargetKind::ManyCore])
            .build()
            .unwrap();
        let (resp, _) = s.dispatch(Request::offload(1, req));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        // a GPU request for the same program must not reuse the
        // many-core pattern (targets are keyed separately)
        let line = proto::offload_request(2, "blackscholes", Lang::C, code);
        let (resp2, _) = s.dispatch_line(&line);
        let rep2 = resp2.get("report").unwrap();
        assert!(rep2.get("pattern_reuse").is_none(), "{}", resp2.to_string());
        assert!(rep2.get("measurements").and_then(|v| v.as_i64()).unwrap() > 0);
        s.shutdown();
    }

    #[test]
    fn per_request_device_set_runs_mixed_placement() {
        let s = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let req = OffloadRequest::source(code, Lang::C)
            .name("smallloops")
            .devices(vec![TargetKind::Gpu, TargetKind::ManyCore])
            .build()
            .unwrap();
        let (resp, _) = s.dispatch(Request::offload(5, req));
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "{}",
            resp.to_string()
        );
        let rep = resp.get("report").unwrap();
        let devices = rep.get("devices").expect("report carries the device set");
        assert!(devices.to_string().contains("many-core"), "{}", devices.to_string());
        assert!(rep.get("placement").is_some(), "report carries the placement");
        s.shutdown();
    }
}
