//! The offload service (`envadapt serve`): the long-lived, multi-tenant
//! daemon the paper's commercial flow describes — user code in any
//! supported language arrives as a request, is converted and verified,
//! and every verified pattern is remembered so the next matching request
//! skips the search entirely.
//!
//! Architecture (see `DESIGN.md` §11 and `docs/OPERATIONS.md`):
//!
//! * **Event loop** — one thread owns the listener and every client
//!   connection, all non-blocking (`run_event_loop`): it accepts, frames
//!   request lines, answers cheap ops (`ping`/`stats`/`metrics` and the
//!   shard-internal `sync_pull`/`sync_push`) inline,
//!   admits offloads into a bounded queue, routes worker completions
//!   back to the right connection by token, enforces per-request
//!   timeouts, and drives graceful drain. No thread-per-connection:
//!   thousands of idle connections cost one poller thread.
//! * **Bounded admission queue** — offloads queue up to
//!   `ServeOptions::queue` deep; beyond that the service *sheds load*
//!   with a versioned `busy` response carrying a `retry_after_ms` hint
//!   instead of buffering unboundedly (`docs/PROTOCOL.md`).
//! * **Worker pool** — [`Service::start`] spawns `pool` OS threads, each
//!   owning an [`OffloadSession`] (devices are not `Send`, so sessions
//!   are built inside their worker thread). A panicking request is
//!   caught ([`std::panic::catch_unwind`]), counted in metrics and
//!   answered with a versioned error; the worker rebuilds its session
//!   and keeps serving. The per-session measurement-worker budget is
//!   `cfg.workers / pool`; the CLI rejects an explicitly oversubscribed
//!   `--pool × --workers` split up front via
//!   [`crate::api::validate_worker_split`] (embedders passing their own
//!   `ServeOptions` should call it too), and an auto-sized pool
//!   (`pool: 0`) is clamped to the budget so it never starves a session.
//! * **Graceful drain** — on the `shutdown` op (or SIGTERM/SIGINT under
//!   `envadapt serve`): stop accepting, refuse new offloads with
//!   `"service is shutting down"`, finish every admitted request, flush
//!   replies, then flush the pattern DB and measurement cache and join
//!   the pool. No accepted request is dropped.
//! * **Observability** — one shared [`crate::metrics::Metrics`] registry
//!   across the pool (threaded through every session), exposed by the
//!   `metrics` op and summarized by `stats`; the field reference lives
//!   in `docs/OPERATIONS.md`.
//! * **Shared learning state** — all worker sessions share one
//!   measurement cache ([`crate::engine::SharedCache`]) and one pattern
//!   DB ([`SharedPatternDb`]): a pattern learned by any worker is
//!   replayed by every worker, and persists across restarts via
//!   `ServeOptions::db_path`.

use crate::api::{OffloadRequest, OffloadSession};
use crate::config::Config;
use crate::engine::{self, SharedCache};
use crate::metrics::{Gauges, Metrics, OpKind, SharedMetrics};
use crate::patterndb::{self, PatternDb, SharedPatternDb};
use crate::proto::{self, Op, Request};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Longest accepted request line (a line past this answers an error and
/// closes the connection — a framing bug, not a request).
const MAX_LINE: usize = 16 * 1024 * 1024;

/// Most learned record lines one `sync_pull` response carries — keeps
/// anti-entropy answers bounded so a replication round can never stall
/// the event loop behind one giant response (pullers resume from the
/// returned `next_seq` cursor).
const SYNC_PULL_BATCH: usize = 512;

/// Idle tick of the event loop: how long it sleeps when no socket made
/// progress (bounds added latency at idle; under load it never sleeps).
const IDLE_TICK: Duration = Duration::from_millis(1);

/// Service-level options (everything else comes from [`Config`]).
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// coordinator pool size; 0 = min(4, host parallelism), clamped to
    /// the measurement-worker budget so auto-sizing never starves a
    /// session
    pub pool: usize,
    /// pattern-DB persistence file: learned patterns are loaded at start
    /// and saved after every insert, so the service resumes warm
    pub db_path: Option<PathBuf>,
    /// admission-queue capacity (queued offloads beyond the ones
    /// executing); 0 = `max(16, 4 × pool)`. When the queue is full the
    /// service sheds load with a `busy` response instead of buffering.
    pub queue: usize,
    /// per-request timeout in milliseconds (admission → response);
    /// 0 = no timeout. Expired requests get a `timed_out` error and any
    /// still-queued work is cancelled.
    pub request_timeout_ms: u64,
    /// backoff hint attached to `busy` responses; 0 = 100 ms
    pub retry_after_ms: u64,
}

impl ServeOptions {
    fn queue_capacity(&self, pool: usize) -> usize {
        if self.queue == 0 {
            (4 * pool).max(16)
        } else {
            self.queue
        }
    }

    fn retry_hint_ms(&self) -> u64 {
        if self.retry_after_ms == 0 {
            100
        } else {
            self.retry_after_ms
        }
    }
}

/// Where a finished job's response goes.
enum ReplySink {
    /// synchronous dispatch ([`Service::dispatch`], stdio transport)
    Channel(Sender<Json>),
    /// the event loop's completion channel, keyed by admission token
    Loop { tx: Sender<Completion>, token: u64 },
}

struct Completion {
    token: u64,
    resp: Json,
}

struct Job {
    id: i64,
    req: OffloadRequest,
    warnings: Vec<String>,
    /// set by whoever answered for the job already (timeout, dead
    /// connection): workers skip cancelled jobs instead of searching
    cancelled: Arc<AtomicBool>,
    reply: ReplySink,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// What admission decided for one offload request.
enum Admission {
    Queued,
    Busy { retry_after_ms: u64 },
    ShuttingDown,
}

/// Shared core state: the bounded queue, the learning state, the metrics
/// registry and the serve limits. Workers and the event loop both hold
/// an `Arc` of this.
struct Inner {
    queue: Mutex<QueueState>,
    ready: Condvar,
    metrics: SharedMetrics,
    db: SharedPatternDb,
    cache: SharedCache,
    pool: usize,
    queue_capacity: usize,
    retry_after_ms: u64,
    request_timeout_ms: u64,
    db_path: Option<PathBuf>,
    /// open client connections (event-loop gauge)
    connections: AtomicU64,
    /// drain in progress: stop admitting offloads
    draining: AtomicBool,
}

impl Inner {
    fn admit(&self, job: Job) -> Admission {
        if self.draining.load(Ordering::SeqCst) {
            return Admission::ShuttingDown;
        }
        let mut q = self.queue.lock().unwrap();
        if q.closed {
            return Admission::ShuttingDown;
        }
        if q.jobs.len() >= self.queue_capacity {
            // load-proportional backoff: estimated queue drain time
            // (depth × recent offload wall average), floored at the
            // configured hint — a router's retry pacing tracks load
            return Admission::Busy {
                retry_after_ms: proto::retry_hint(
                    q.jobs.len(),
                    self.metrics.avg_wall_ms(),
                    self.retry_after_ms,
                ),
            };
        }
        q.jobs.push_back(job);
        drop(q);
        self.ready.notify_one();
        Admission::Queued
    }

    fn gauges(&self) -> Gauges {
        let (cache_entries, cache_hits, cache_misses) = {
            let c = self.cache.lock().unwrap();
            (c.len(), c.hit_count(), c.miss_count())
        };
        Gauges {
            pool: self.pool,
            queue_depth: self.queue.lock().unwrap().jobs.len(),
            queue_capacity: self.queue_capacity,
            connections_open: self.connections.load(Ordering::Relaxed) as usize,
            cache_entries,
            cache_hits,
            cache_misses,
            ..Gauges::default()
        }
        .with_db(&self.db.lock().unwrap())
    }
}

/// The shared service core: event-loop-ready admission queue + worker
/// pool + learning state + metrics.
pub struct Service {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    /// Build the shared state and spawn the session worker pool.
    ///
    /// An explicit `opts.pool` is honored as-is (the budget split
    /// bottoms out at one measurement worker per session): the
    /// measurement budget defaults to the *host's* parallelism, so
    /// hard-failing here would make a fixed `pool` value start or not
    /// start depending on the machine. Front ends that take both knobs
    /// from a user should reject an oversubscribed split up front via
    /// [`crate::api::validate_worker_split`], as the CLI does.
    pub fn start(cfg: Config, opts: &ServeOptions) -> Service {
        let budget = cfg.effective_workers();
        let pool = if opts.pool == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(4)
                .min(budget)
                .max(1)
        } else {
            opts.pool
        };
        let mut cfg = cfg;
        cfg.pattern_db_path = opts.db_path.clone();
        // split the measurement-worker budget across the pool so the two
        // pool levels don't multiply into pool × cfg.workers threads
        let mut wcfg = cfg.clone();
        wcfg.workers = (budget / pool).max(1);
        let db = patterndb::shared(PatternDb::open_or_builtin(opts.db_path.as_deref()));
        let cache = engine::cache_for(&cfg);
        let inner = Arc::new(Inner {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            metrics: Metrics::shared(),
            db,
            cache,
            pool,
            queue_capacity: opts.queue_capacity(pool),
            retry_after_ms: opts.retry_hint_ms(),
            request_timeout_ms: opts.request_timeout_ms,
            db_path: opts.db_path.clone(),
            connections: AtomicU64::new(0),
            draining: AtomicBool::new(false),
        });
        let mut workers = Vec::with_capacity(pool);
        for wid in 0..pool {
            let wcfg = wcfg.clone();
            let inner = inner.clone();
            workers.push(std::thread::spawn(move || worker_loop(wid, wcfg, inner)));
        }
        Service { inner, workers }
    }

    /// Handle one request line; returns the response and whether the
    /// caller should shut the whole service down. Synchronous: offloads
    /// block until served, shed (`busy`) or timed out — this is the
    /// stdio transport and the embedding entry; the TCP event loop
    /// multiplexes through the queue directly instead.
    pub fn dispatch_line(&self, line: &str) -> (Json, bool) {
        match Request::parse_line(line) {
            Ok(req) => self.dispatch(req),
            Err(e) => {
                self.inner.metrics.note_op(OpKind::Invalid);
                // echo the id when the line was at least JSON, so
                // pipelining clients can still match the error
                let resp = proto::err(proto::line_id(line), &e.to_string());
                self.inner.metrics.note_response(&resp);
                (resp, false)
            }
        }
    }

    /// Handle one parsed request (synchronous; see
    /// [`Service::dispatch_line`]).
    pub fn dispatch(&self, req: Request) -> (Json, bool) {
        let Request { id, op, warnings } = req;
        self.inner.metrics.note_op(op_kind(&op));
        let (resp, quit) = match op {
            Op::Offload(r) => (self.offload_blocking(id, *r, warnings), false),
            Op::Stats => (proto::ok_stats(id, self.stats_json(), &warnings), false),
            Op::Metrics => (proto::ok_metrics(id, self.metrics_json(), &warnings), false),
            Op::Ping => (proto::ok_simple(id, "ping", &warnings), false),
            Op::SyncPull { since } => (self.sync_pull_resp(id, since, &warnings), false),
            Op::SyncPush { records } => (self.sync_push_resp(id, &records, &warnings), false),
            Op::Shutdown => {
                self.inner.draining.store(true, Ordering::SeqCst);
                (proto::ok_simple(id, "shutdown", &warnings), true)
            }
        };
        self.inner.metrics.note_response(&resp);
        (resp, quit)
    }

    fn offload_blocking(&self, id: i64, req: OffloadRequest, warnings: Vec<String>) -> Json {
        let (tx, rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let job =
            Job { id, req, warnings, cancelled: cancelled.clone(), reply: ReplySink::Channel(tx) };
        match self.inner.admit(job) {
            Admission::Busy { retry_after_ms } => proto::busy(id, retry_after_ms),
            Admission::ShuttingDown => proto::err(id, "service is shutting down"),
            Admission::Queued => {
                let timeout_ms = self.inner.request_timeout_ms;
                if timeout_ms == 0 {
                    rx.recv().unwrap_or_else(|_| proto::err(id, "worker died before replying"))
                } else {
                    match rx.recv_timeout(Duration::from_millis(timeout_ms)) {
                        Ok(resp) => resp,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            cancelled.store(true, Ordering::SeqCst);
                            proto::timeout(id, timeout_ms)
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            proto::err(id, "worker died before replying")
                        }
                    }
                }
            }
        }
    }

    /// The `stats` op payload: the legacy summary counters plus the
    /// admission-control counters (the `metrics` op carries the full
    /// structured surface).
    pub fn stats_json(&self) -> Json {
        let m = &self.inner.metrics;
        let g = self.inner.gauges();
        Json::obj()
            .set("workers", self.inner.pool)
            .set("uptime_s", m.uptime_s())
            .set("requests", m.requests_total() as i64)
            .set("offloads", m.offloads_total() as i64)
            .set("errors", m.responses_error() as i64)
            .set("pattern_reuse_hits", m.offloads_replayed() as i64)
            .set("patterns_learned", m.patterns_learned() as i64)
            .set("learned_records", g.learned_records)
            .set("search_measurements", m.search_measurements() as i64)
            .set("cache_entries", g.cache_entries)
            .set("cache_hits", g.cache_hits as i64)
            .set("cache_misses", g.cache_misses as i64)
            .set("queue_depth", g.queue_depth)
            .set("queue_capacity", g.queue_capacity)
            .set("busy_rejections", m.responses_busy() as i64)
            .set("timeouts", m.responses_timeout() as i64)
            .set("worker_panics", m.worker_panics() as i64)
    }

    /// The `metrics` op payload (full fixed-schema snapshot; field
    /// reference in `docs/OPERATIONS.md`).
    pub fn metrics_json(&self) -> Json {
        self.inner.metrics.snapshot(&self.inner.gauges())
    }

    /// The `sync_pull` op: a bounded batch of learned record lines
    /// appended at or after entry cursor `since`, plus the cursor to
    /// resume from (anti-entropy; see `proto::Op::SyncPull`).
    fn sync_pull_resp(&self, id: i64, since: usize, warnings: &[String]) -> Json {
        let (records, next) =
            self.inner.db.lock().unwrap().sync_lines_since(since, SYNC_PULL_BATCH);
        proto::ok_sync_pull(id, &records, next, warnings)
    }

    /// The `sync_push` op: absorb record lines replicated from a sibling
    /// shard with merge-on-write semantics (the faster plan wins).
    fn sync_push_resp(&self, id: i64, records: &[String], warnings: &[String]) -> Json {
        let merged = self.inner.db.lock().unwrap().absorb_lines(records);
        proto::ok_sync_push(id, merged, warnings)
    }

    /// Handle on the shared metrics registry (tests, embedding).
    pub fn metrics(&self) -> SharedMetrics {
        self.inner.metrics.clone()
    }

    /// Handle on the shared pattern DB (tests, introspection).
    pub fn db(&self) -> SharedPatternDb {
        self.inner.db.clone()
    }

    /// Close the job queue, join the worker pool and flush learned state
    /// (pattern DB + measurement cache) to disk.
    pub fn shutdown(self) {
        {
            let mut q = self.inner.queue.lock().unwrap();
            q.closed = true;
        }
        self.inner.ready.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
        // drain contract: learned state is durable once shutdown returns
        // (inserts already save incrementally; this covers the tail)
        if let Some(path) = &self.inner.db_path {
            let _ = self.inner.db.lock().unwrap().flush(path);
        }
        let _ = self.inner.cache.lock().unwrap().save();
    }
}

fn op_kind(op: &Op) -> OpKind {
    match op {
        Op::Offload(_) => OpKind::Offload,
        Op::Stats => OpKind::Stats,
        Op::Metrics => OpKind::Metrics,
        Op::Ping => OpKind::Ping,
        Op::Shutdown => OpKind::Shutdown,
        Op::SyncPull { .. } | Op::SyncPush { .. } => OpKind::Sync,
    }
}

// ---------------------------------------------------------------------------
// worker pool
// ---------------------------------------------------------------------------

fn worker_loop(wid: usize, cfg: Config, inner: Arc<Inner>) {
    // Each worker owns one OffloadSession, built inside this thread
    // (devices are not Send) and living for the whole service, so PJRT
    // executable caches stay warm across requests. The session keeps one
    // coordinator per request variant; all sessions share the cache,
    // pattern DB and metrics registry. After a caught panic the session
    // is dropped and rebuilt (None), so a request that corrupted session
    // state cannot poison the ones after it.
    let mut session: Option<OffloadSession> = None;
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break j;
                }
                if q.closed {
                    return;
                }
                q = inner.ready.wait(q).unwrap();
            }
        };
        if job.cancelled.load(Ordering::SeqCst) {
            // answered already (timeout / dead connection): don't search
            continue;
        }
        let resp = handle_offload(wid, &cfg, &mut session, &job, &inner);
        send_reply(&job.reply, resp);
    }
}

fn send_reply(sink: &ReplySink, resp: Json) {
    // a dropped receiver just means the client (or canceller) went away
    match sink {
        ReplySink::Channel(tx) => {
            let _ = tx.send(resp);
        }
        ReplySink::Loop { tx, token } => {
            let _ = tx.send(Completion { token: *token, resp });
        }
    }
}

/// Serve one offload, containing panics: a panicking request is counted
/// and answered with a versioned error, the worker's session is dropped
/// (rebuilt lazily for the next job), and the connection and the pool
/// both survive.
fn handle_offload(
    wid: usize,
    cfg: &Config,
    session_slot: &mut Option<OffloadSession>,
    job: &Job,
    inner: &Inner,
) -> Json {
    let session = session_slot.get_or_insert_with(|| {
        let mut s = OffloadSession::with_shared(cfg.clone(), inner.cache.clone(), inner.db.clone());
        s.set_metrics(inner.metrics.clone());
        s
    });
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        test_failpoint(&job.req.name);
        session.offload(&job.req)
    }));
    match outcome {
        Ok(Ok(report)) => proto::ok_offload(job.id, &report, wid, &job.warnings),
        Ok(Err(e)) => proto::err(job.id, &e.to_string()),
        Err(payload) => {
            // the request may have left the session in an arbitrary
            // state mid-search: drop it so the next job starts clean
            *session_slot = None;
            inner.metrics.record_worker_panic();
            proto::err(
                job.id,
                &format!(
                    "internal error: offload worker panicked: {}",
                    panic_message(payload.as_ref())
                ),
            )
        }
    }
}

/// Debug-build fault injection for the serve test suite (magic request
/// names; compiled out of release builds).
fn test_failpoint(name: &str) {
    if !cfg!(debug_assertions) {
        return;
    }
    if name == "__envadapt_test_panic" {
        panic!("injected test panic");
    }
    if name == "__envadapt_test_slow" {
        std::thread::sleep(Duration::from_millis(400));
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// event loop (TCP transport)
// ---------------------------------------------------------------------------

/// One multiplexed client connection owned by the event loop.
struct EvConn {
    stream: TcpStream,
    /// unparsed request bytes (partial trailing line)
    rbuf: Vec<u8>,
    /// unwritten response bytes
    wbuf: Vec<u8>,
    /// client closed its write side: no more requests, but queued
    /// responses still get delivered (half-close friendly)
    eof: bool,
    /// connection is unusable (I/O error, protocol abuse): reap now
    dead: bool,
    /// admitted offloads not yet answered on this connection
    inflight: usize,
}

/// An admitted offload the event loop is waiting on, keyed by token.
struct EvPending {
    conn: u64,
    id: i64,
    deadline: Option<Instant>,
    cancelled: Arc<AtomicBool>,
}

/// Event-loop bookkeeping shared by the per-line handler.
struct LoopState {
    pending: HashMap<u64, EvPending>,
    next_token: u64,
    completions: Sender<Completion>,
}

fn push_resp(metrics: &SharedMetrics, conn: &mut EvConn, resp: &Json) {
    metrics.note_response(resp);
    conn.wbuf.extend_from_slice(resp.to_string().as_bytes());
    conn.wbuf.push(b'\n');
}

/// Handle one framed request line from connection `cid`. Cheap ops are
/// answered inline into the connection's write buffer; offloads are
/// admitted (or shed) into the bounded queue with the completion routed
/// back by token. `shutdown` flips the service into drain.
fn handle_line(service: &Service, cid: u64, conn: &mut EvConn, line: &str, st: &mut LoopState) {
    let inner = &service.inner;
    let m = &inner.metrics;
    let req = match Request::parse_line(line) {
        Ok(req) => req,
        Err(e) => {
            m.note_op(OpKind::Invalid);
            push_resp(m, conn, &proto::err(proto::line_id(line), &e.to_string()));
            return;
        }
    };
    let Request { id, op, warnings } = req;
    m.note_op(op_kind(&op));
    match op {
        Op::Ping => push_resp(m, conn, &proto::ok_simple(id, "ping", &warnings)),
        Op::Stats => push_resp(m, conn, &proto::ok_stats(id, service.stats_json(), &warnings)),
        Op::Metrics => {
            push_resp(m, conn, &proto::ok_metrics(id, service.metrics_json(), &warnings))
        }
        Op::SyncPull { since } => {
            push_resp(m, conn, &service.sync_pull_resp(id, since, &warnings))
        }
        Op::SyncPush { records } => {
            push_resp(m, conn, &service.sync_push_resp(id, &records, &warnings))
        }
        Op::Shutdown => {
            // begin graceful drain; the ack is flushed before the loop
            // exits, and admitted offloads still complete
            inner.draining.store(true, Ordering::SeqCst);
            push_resp(m, conn, &proto::ok_simple(id, "shutdown", &warnings));
        }
        Op::Offload(r) => {
            let token = st.next_token;
            st.next_token += 1;
            let cancelled = Arc::new(AtomicBool::new(false));
            let deadline = (inner.request_timeout_ms > 0)
                .then(|| Instant::now() + Duration::from_millis(inner.request_timeout_ms));
            let job = Job {
                id,
                req: *r,
                warnings,
                cancelled: cancelled.clone(),
                reply: ReplySink::Loop { tx: st.completions.clone(), token },
            };
            match inner.admit(job) {
                Admission::Queued => {
                    st.pending.insert(token, EvPending { conn: cid, id, deadline, cancelled });
                    conn.inflight += 1;
                }
                Admission::Busy { retry_after_ms } => {
                    push_resp(m, conn, &proto::busy(id, retry_after_ms));
                }
                Admission::ShuttingDown => {
                    push_resp(m, conn, &proto::err(id, "service is shutting down"));
                }
            }
        }
    }
}

/// The multiplexing event loop over an already-bound listener: owns
/// every connection, frames lines, admits offloads, routes completions,
/// enforces timeouts, and runs graceful drain to completion. Returns
/// once drain has finished (`shutdown` op or a termination signal).
fn run_event_loop(listener: TcpListener, service: &Service) -> Result<()> {
    listener.set_nonblocking(true)?;
    let inner = &service.inner;
    let (ctx, crx) = mpsc::channel::<Completion>();
    let mut st = LoopState { pending: HashMap::new(), next_token: 0, completions: ctx };
    let mut conns: HashMap<u64, EvConn> = HashMap::new();
    let mut next_conn: u64 = 0;
    let mut listener = Some(listener);

    loop {
        let mut progress = false;

        // 0. external drain signals (SIGTERM/SIGINT under `envadapt serve`)
        if sig::requested() {
            inner.draining.store(true, Ordering::SeqCst);
        }
        let draining = inner.draining.load(Ordering::SeqCst);
        if draining && listener.is_some() {
            listener = None; // stop accepting
        }

        // 1. accept every waiting connection
        if let Some(l) = &listener {
            loop {
                match l.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.set_nodelay(true);
                        conns.insert(
                            next_conn,
                            EvConn {
                                stream,
                                rbuf: Vec::new(),
                                wbuf: Vec::new(),
                                eof: false,
                                dead: false,
                                inflight: 0,
                            },
                        );
                        next_conn += 1;
                        progress = true;
                    }
                    // WouldBlock (nothing waiting) and transient accept
                    // errors both end this tick's accept burst
                    Err(_) => break,
                }
            }
        }

        // 2. read and handle complete request lines
        let mut buf = [0u8; 8192];
        for (&cid, conn) in conns.iter_mut() {
            if conn.eof || conn.dead {
                continue;
            }
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&buf[..n]);
                        progress = true;
                        if conn.rbuf.len() > MAX_LINE {
                            let resp = proto::err(0, "request line too long");
                            push_resp(&inner.metrics, conn, &resp);
                            conn.dead = true;
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
            if conn.dead {
                continue;
            }
            let mut lines: Vec<String> = Vec::new();
            while let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') {
                let mut raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                raw.pop();
                lines.push(String::from_utf8_lossy(&raw).into_owned());
            }
            for line in lines {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                progress = true;
                handle_line(service, cid, conn, line, &mut st);
            }
        }

        // 3. route worker completions back to their connections
        while let Ok(c) = crx.try_recv() {
            progress = true;
            if let Some(p) = st.pending.remove(&c.token) {
                if let Some(conn) = conns.get_mut(&p.conn) {
                    push_resp(&inner.metrics, conn, &c.resp);
                    conn.inflight = conn.inflight.saturating_sub(1);
                }
            }
            // unknown token: the request was already answered (timeout)
            // or its connection died — the late result is discarded
        }

        // 4. expire admitted requests past their deadline
        if inner.request_timeout_ms > 0 {
            let now = Instant::now();
            let expired: Vec<u64> = st
                .pending
                .iter()
                .filter(|(_, p)| p.deadline.is_some_and(|d| d <= now))
                .map(|(&t, _)| t)
                .collect();
            for token in expired {
                progress = true;
                let p = st.pending.remove(&token).expect("token just listed");
                p.cancelled.store(true, Ordering::SeqCst);
                if let Some(conn) = conns.get_mut(&p.conn) {
                    push_resp(
                        &inner.metrics,
                        conn,
                        &proto::timeout(p.id, inner.request_timeout_ms),
                    );
                    conn.inflight = conn.inflight.saturating_sub(1);
                }
            }
        }

        // 5. flush write buffers
        for conn in conns.values_mut() {
            if conn.dead {
                continue;
            }
            while !conn.wbuf.is_empty() {
                match conn.stream.write(&conn.wbuf) {
                    Ok(0) => {
                        conn.dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.wbuf.drain(..n);
                        progress = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
        }

        // 6. reap: dead connections cancel their in-flight work; cleanly
        //    closed ones linger until every queued response is delivered
        let reap: Vec<u64> = conns
            .iter()
            .filter(|(_, c)| c.dead || (c.eof && c.inflight == 0 && c.wbuf.is_empty()))
            .map(|(&cid, _)| cid)
            .collect();
        for cid in reap {
            let c = conns.remove(&cid).expect("conn just listed");
            if c.dead {
                st.pending.retain(|_, p| {
                    if p.conn == cid {
                        p.cancelled.store(true, Ordering::SeqCst);
                        false
                    } else {
                        true
                    }
                });
            }
        }
        inner.connections.store(conns.len() as u64, Ordering::Relaxed);

        // 7. drain completion: every admitted request answered — deliver
        //    the remaining bytes with a short blocking grace period
        if draining && st.pending.is_empty() {
            for conn in conns.values_mut() {
                if conn.dead || conn.wbuf.is_empty() {
                    continue;
                }
                let _ = conn.stream.set_nonblocking(false);
                let _ = conn.stream.set_write_timeout(Some(Duration::from_secs(2)));
                let _ = conn.stream.write_all(&conn.wbuf);
                let _ = conn.stream.flush();
            }
            return Ok(());
        }

        if !progress {
            std::thread::sleep(IDLE_TICK);
        }
    }
}

/// SIGTERM/SIGINT → graceful drain, installed only by the foreground
/// daemon entry points (`envadapt serve`); background/test servers drain
/// via the `shutdown` op instead. A handler that only sets a flag is
/// async-signal-safe; the event loop polls the flag every tick (the
/// router's loop in [`crate::router`] polls the same flag, so one
/// SIGTERM drains whichever daemon flavor is in the foreground).
pub(crate) mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static REQUESTED: AtomicBool = AtomicBool::new(false);

    pub fn requested() -> bool {
        REQUESTED.load(Ordering::SeqCst)
    }

    #[cfg(unix)]
    pub fn install() {
        extern "C" fn on_signal(_sig: i32) {
            REQUESTED.store(true, Ordering::SeqCst);
        }
        // no libc crate offline: declare the two symbols we need (std
        // already links the platform libc)
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}
}

// ---------------------------------------------------------------------------
// transports
// ---------------------------------------------------------------------------

/// Serve an already-bound listener with the multiplexing event loop.
/// Returns after graceful drain (a client's `shutdown` op, or
/// SIGTERM/SIGINT when [`install_signal_handlers`] ran): accepted
/// requests are finished and learned state is flushed before this
/// returns.
pub fn serve_listener(listener: TcpListener, cfg: Config, opts: ServeOptions) -> Result<()> {
    let service = Service::start(cfg, &opts);
    let r = run_event_loop(listener, &service);
    service.shutdown();
    r
}

/// Install the daemon's SIGTERM/SIGINT → graceful-drain handlers
/// (foreground `envadapt serve` only; no-op off unix).
pub fn install_signal_handlers() {
    sig::install();
}

/// Bind `addr` (e.g. `127.0.0.1:7777`; port 0 picks an ephemeral port)
/// and serve until drained. Blocking — this is what `envadapt serve`
/// runs.
pub fn serve_tcp(addr: &str, cfg: Config, opts: ServeOptions) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("envadapt serve: listening on {}", listener.local_addr()?);
    serve_listener(listener, cfg, opts)
}

/// Serve line-delimited JSON on stdin/stdout (single-client mode; offload
/// work still runs on the session pool). Returns at EOF or on the
/// `shutdown` op. Requests are served synchronously in arrival order;
/// admission control still applies (`busy` can only occur with a
/// pipelining writer, timeouts whenever configured).
pub fn serve_stdio(cfg: Config, opts: ServeOptions) -> Result<()> {
    let service = Service::start(cfg, &opts);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, quit) = service.dispatch_line(&line);
        out.write_all(resp.to_string().as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
        if quit {
            break;
        }
    }
    service.shutdown();
    Ok(())
}

/// Handle on a server running on a background thread (tests, examples,
/// embedding).
pub struct ServerHandle {
    addr: SocketAddr,
    thread: JoinHandle<Result<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the server to drain (a `shutdown` request over a fresh
    /// connection) and wait for it to wind down. Graceful: admitted
    /// offloads finish and their responses are delivered first. If the
    /// server is already draining (a client sent `shutdown`, SIGTERM),
    /// the connect fails and this just joins.
    pub fn shutdown(self) -> Result<()> {
        if let Ok(mut stream) = TcpStream::connect(self.addr) {
            let _ = stream.write_all(b"{\"op\":\"shutdown\",\"id\":0}\n");
            let _ = stream.flush();
            let mut line = String::new();
            let _ = BufReader::new(stream).read_line(&mut line);
        }
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("server thread panicked")),
        }
    }
}

/// Bind `addr` and serve on a background thread; the returned handle
/// carries the bound address (bind port 0 for an ephemeral port).
pub fn spawn_tcp(cfg: Config, opts: ServeOptions, addr: &str) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let thread = std::thread::spawn(move || serve_listener(listener, cfg, opts));
    Ok(ServerHandle { addr, thread })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::TargetKind;
    use crate::ir::Lang;

    fn service() -> Service {
        Service::start(Config::fast_sim(), &ServeOptions { pool: 2, ..Default::default() })
    }

    #[test]
    fn dispatch_ping_stats_and_errors() {
        let s = service();
        let (resp, quit) = s.dispatch_line(r#"{"op":"ping","id":5}"#);
        assert!(!quit);
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(5));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            resp.get("schema_version").and_then(|v| v.as_i64()),
            Some(crate::api::SCHEMA_VERSION),
            "every response is versioned: {}",
            resp.to_string()
        );

        let (resp, _) = s.dispatch_line("garbage");
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));

        let (resp, quit) = s.dispatch_line(r#"{"op":"stats","id":6}"#);
        assert!(!quit);
        let stats = resp.get("stats").expect("stats payload");
        assert_eq!(stats.get("requests").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(stats.get("errors").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(stats.get("workers").and_then(|v| v.as_i64()), Some(2));
        // admission-control counters ride along on the legacy summary
        assert_eq!(stats.get("queue_depth").and_then(|v| v.as_i64()), Some(0));
        assert_eq!(stats.get("busy_rejections").and_then(|v| v.as_i64()), Some(0));

        let (_, quit) = s.dispatch_line(r#"{"op":"shutdown","id":7}"#);
        assert!(quit);
        s.shutdown();
    }

    #[test]
    fn unknown_op_lists_supported_ops() {
        let s = service();
        let (resp, quit) = s.dispatch_line(r#"{"op":"dance","id":3}"#);
        assert!(!quit);
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(3));
        let err = resp.get("error").and_then(|v| v.as_str()).unwrap();
        assert!(
            err.contains("supported: offload, stats, metrics, ping, shutdown"),
            "unknown-op error must name the supported ops: {err}"
        );
        s.shutdown();
    }

    #[test]
    fn unknown_request_fields_surface_as_warnings() {
        let s = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let line = format!(
            r#"{{"op":"offload","id":4,"name":"smallloops","lang":"c","code":{},"tarmget":"gpu"}}"#,
            Json::Str(code.to_string()).to_string()
        );
        let (resp, _) = s.dispatch_line(&line);
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", resp.to_string());
        let warnings = resp.get("warnings").and_then(|v| v.items()).expect("warnings array");
        assert_eq!(warnings.len(), 1, "{}", resp.to_string());
        assert!(warnings[0].as_str().unwrap().contains("tarmget"));
        // well-formed requests carry no warnings array at all
        let (resp, _) = s.dispatch_line(r#"{"op":"ping","id":5}"#);
        assert!(resp.get("warnings").is_none());
        s.shutdown();
    }

    #[test]
    fn offload_learns_then_replays() {
        let s = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let line = proto::offload_request(1, "smallloops", Lang::C, code);
        let (r1, _) = s.dispatch_line(&line);
        assert_eq!(r1.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", r1.to_string());
        let rep1 = r1.get("report").unwrap();
        assert!(rep1.get("measurements").and_then(|v| v.as_i64()).unwrap() > 0);
        assert!(rep1.get("pattern_reuse").is_none());

        let (r2, _) = s.dispatch_line(&line);
        let rep2 = r2.get("report").unwrap();
        assert_eq!(rep2.get("measurements").and_then(|v| v.as_i64()), Some(0));
        assert!(rep2.get("pattern_reuse").is_some());
        assert_eq!(rep2.get("gene"), rep1.get("gene"));

        let (stats, _) = s.dispatch_line(r#"{"op":"stats","id":9}"#);
        let stats = stats.get("stats").unwrap();
        assert_eq!(stats.get("offloads").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(stats.get("pattern_reuse_hits").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(stats.get("patterns_learned").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(stats.get("learned_records").and_then(|v| v.as_i64()), Some(1));

        // the metrics op sees the same traffic, in the structured schema
        let (mresp, _) = s.dispatch_line(r#"{"op":"metrics","id":10}"#);
        assert_eq!(mresp.get("ok").and_then(|v| v.as_bool()), Some(true));
        let m = mresp.get("metrics").expect("metrics payload");
        let o = m.get("offloads").unwrap();
        assert_eq!(o.get("total").and_then(|v| v.as_i64()), Some(2));
        assert_eq!(o.get("searched").and_then(|v| v.as_i64()), Some(1));
        assert_eq!(o.get("replayed").and_then(|v| v.as_i64()), Some(1));
        assert!(
            m.get("search").unwrap().get("measurements").and_then(|v| v.as_i64()).unwrap() > 0
        );
        s.shutdown();
    }

    #[test]
    fn sync_ops_replicate_learned_patterns_between_services() {
        let a = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let (r, _) = a.dispatch_line(&proto::offload_request(1, "smallloops", Lang::C, code));
        assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", r.to_string());

        // pull a's learned slice off the wire ...
        let (pull, _) = a.dispatch_line(r#"{"op":"sync_pull","id":2,"since":0}"#);
        assert_eq!(pull.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", pull.to_string());
        let records = pull.get("records").and_then(|v| v.items()).expect("records array");
        assert_eq!(records.len(), 1, "one learned record so far");
        let next = pull.get("next_seq").and_then(|v| v.as_i64()).unwrap();
        assert!(next >= 1);
        // ... and an incremental pull from the cursor is empty
        let (tail, _) =
            a.dispatch_line(&format!(r#"{{"op":"sync_pull","id":3,"since":{next}}}"#));
        assert_eq!(
            tail.get("records").and_then(|v| v.items()).map(|x| x.len()),
            Some(0),
            "nothing new since the cursor"
        );

        // push the slice into a fresh service: it replays with zero
        // measurements, never having searched this program itself
        let b = service();
        let push = Json::obj()
            .set("op", "sync_push")
            .set("id", 4)
            .set("records", Json::Arr(records.to_vec()))
            .to_string();
        let (pushed, _) = b.dispatch_line(&push);
        assert_eq!(pushed.get("ok").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(pushed.get("merged").and_then(|v| v.as_i64()), Some(1));
        let (r2, _) = b.dispatch_line(&proto::offload_request(5, "smallloops", Lang::C, code));
        let rep = r2.get("report").unwrap();
        assert_eq!(rep.get("measurements").and_then(|v| v.as_i64()), Some(0));
        assert!(rep.get("pattern_reuse").is_some(), "{}", r2.to_string());
        // a second identical push changes nothing (idempotent)
        let (pushed2, _) = b.dispatch_line(&push);
        assert_eq!(pushed2.get("merged").and_then(|v| v.as_i64()), Some(0));

        // both sync ops were counted under requests_by_op.sync
        let (m, _) = a.dispatch_line(r#"{"op":"metrics","id":9}"#);
        let by_op = m.get("metrics").unwrap().get("requests_by_op").unwrap();
        assert_eq!(by_op.get("sync").and_then(|v| v.as_i64()), Some(2));
        a.shutdown();
        b.shutdown();
    }

    #[test]
    fn per_request_target_override() {
        let s = service();
        let code = crate::workloads::get("blackscholes", Lang::C).unwrap().code;
        let req = OffloadRequest::source(code, Lang::C)
            .name("blackscholes")
            .devices(vec![TargetKind::ManyCore])
            .build()
            .unwrap();
        let (resp, _) = s.dispatch(Request::offload(1, req));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        // a GPU request for the same program must not reuse the
        // many-core pattern (targets are keyed separately)
        let line = proto::offload_request(2, "blackscholes", Lang::C, code);
        let (resp2, _) = s.dispatch_line(&line);
        let rep2 = resp2.get("report").unwrap();
        assert!(rep2.get("pattern_reuse").is_none(), "{}", resp2.to_string());
        assert!(rep2.get("measurements").and_then(|v| v.as_i64()).unwrap() > 0);
        s.shutdown();
    }

    #[test]
    fn per_request_device_set_runs_mixed_placement() {
        let s = service();
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let req = OffloadRequest::source(code, Lang::C)
            .name("smallloops")
            .devices(vec![TargetKind::Gpu, TargetKind::ManyCore])
            .build()
            .unwrap();
        let (resp, _) = s.dispatch(Request::offload(5, req));
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "{}",
            resp.to_string()
        );
        let rep = resp.get("report").unwrap();
        let devices = rep.get("devices").expect("report carries the device set");
        assert!(devices.to_string().contains("many-core"), "{}", devices.to_string());
        assert!(rep.get("placement").is_some(), "report carries the placement");
        s.shutdown();
    }

    #[test]
    fn draining_service_refuses_new_offloads() {
        let s = service();
        let (_, quit) = s.dispatch_line(r#"{"op":"shutdown","id":1}"#);
        assert!(quit);
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let (resp, _) = s.dispatch_line(&proto::offload_request(2, "smallloops", Lang::C, code));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert!(resp
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap()
            .contains("service is shutting down"));
        // cheap ops still answer during drain (operators watch the drain)
        let (resp, _) = s.dispatch_line(r#"{"op":"metrics","id":3}"#);
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
        s.shutdown();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn per_request_timeout_answers_versioned_error() {
        let s = Service::start(
            Config::fast_sim(),
            &ServeOptions { pool: 1, request_timeout_ms: 50, ..Default::default() },
        );
        let req = OffloadRequest::source("void main() { }", Lang::C)
            .name("__envadapt_test_slow")
            .build()
            .unwrap();
        let (resp, _) = s.dispatch(Request::offload(1, req));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false), "{}", resp.to_string());
        assert_eq!(resp.get("timed_out").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(
            resp.get("schema_version").and_then(|v| v.as_i64()),
            Some(crate::api::SCHEMA_VERSION)
        );
        let (mresp, _) = s.dispatch_line(r#"{"op":"metrics","id":2}"#);
        let m = mresp.get("metrics").unwrap();
        assert_eq!(
            m.get("responses").unwrap().get("timeout").and_then(|v| v.as_i64()),
            Some(1)
        );
        s.shutdown();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn worker_panic_is_caught_counted_and_answered() {
        let s = Service::start(Config::fast_sim(), &ServeOptions { pool: 1, ..Default::default() });
        let req = OffloadRequest::source("void main() { }", Lang::C)
            .name("__envadapt_test_panic")
            .build()
            .unwrap();
        let (resp, _) = s.dispatch(Request::offload(1, req));
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false));
        assert!(resp.get("error").and_then(|v| v.as_str()).unwrap().contains("panicked"));
        assert_eq!(
            resp.get("schema_version").and_then(|v| v.as_i64()),
            Some(crate::api::SCHEMA_VERSION)
        );
        // the pool survived: the next request is served normally
        let code = crate::workloads::get("smallloops", Lang::C).unwrap().code;
        let (r2, _) = s.dispatch_line(&proto::offload_request(2, "smallloops", Lang::C, code));
        assert_eq!(r2.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", r2.to_string());
        let (mresp, _) = s.dispatch_line(r#"{"op":"metrics","id":3}"#);
        let m = mresp.get("metrics").unwrap();
        assert_eq!(m.get("worker_panics").and_then(|v| v.as_i64()), Some(1));
        s.shutdown();
    }
}
