//! The environment-adaptive offloading coordinator — Fig. 1's flow,
//! end-to-end (the paper's system contribution).
//!
//! For one application in any supported language:
//!
//! 1. **Code analysis** — parse to the language-independent IR, build the
//!    loop/variable/function-block tables (`frontend`, `analysis`).
//! 2. **Function-block offload trial** (§4.2, tried *first* because
//!    algorithm-tuned blocks beat per-loop parallelization): name-match +
//!    clone-similarity candidates against the pattern DB, measured
//!    individually and in combination.
//! 3. **Loop-statement offload trial** — GA over the remaining
//!    parallelizable loops (function-block-replaced nests are excluded,
//!    §4.2: 機能ブロック部分を抜いたコードに対して試行), each gene measured
//!    in the verification environment with transfer-hoisting applied.
//! 4. **Final pattern selection** — fastest correct candidate wins; the
//!    report carries per-language directive-annotated source (OpenACC /
//!    PyCUDA / parallel-stream) plus every number the benches need.

use crate::analysis::{self, ProgramAnalysis};
use crate::clone::char_vector_program;
use crate::config::Config;
use crate::device::{
    DeviceFactory, DeviceStats, MultiDevice, MultiDeviceFactory, TargetKind,
};
use crate::engine::{self, MeasurementEngine, SharedCache, SharedCompiledCache};
use crate::frontend::{self, render};
use crate::funcblock::{self, Candidate, FuncBlockReport};
use crate::ga::{self, GaResult};
use crate::ir::{Lang, LoopId, Program};
use crate::measure::{Measurement, Measurer};
use crate::patterndb::{self, LearnedPlan, PatternDb, PatternRecord, SharedPatternDb};
use crate::placement::DeviceSet;
use crate::util::json::Json;
use crate::vm::{ExecEngine, ExecPlan};
use anyhow::Result;
use std::collections::HashSet;

/// Everything the coordinator learned about one application.
#[derive(Debug, Clone)]
pub struct OffloadReport {
    pub app: String,
    pub lang: Lang,
    /// CPU-only modeled seconds
    pub baseline_s: f64,
    /// best offload pattern's modeled seconds
    pub final_s: f64,
    pub funcblock: Option<FuncBlockReport>,
    pub ga: Option<GaResult>,
    /// loop ids the gene indexes (after function-block exclusion)
    pub gene_loops: Vec<LoopId>,
    /// winning placement gene: `devices`-dependent bits per loop slot
    /// (one bit per loop in the single-destination case)
    pub best_gene: Vec<bool>,
    /// the heterogeneous destination set the search placed onto
    pub devices: Vec<TargetKind>,
    /// decoded destination per gene loop (aligned with `gene_loops`;
    /// `None` = stayed on the CPU)
    pub placement: Vec<Option<TargetKind>>,
    /// modeled energy of the final verified run (joules)
    pub energy_j: f64,
    /// the energy weight the fitness used (0 = pure time)
    pub power_weight: f64,
    pub final_plan: ExecPlan,
    /// final verification measurement
    pub final_measurement: Measurement,
    /// offload-directive-annotated source in the app's own language
    pub annotated_source: String,
    /// total distinct measurements spent (func-block trials + GA)
    pub total_measurements: usize,
    /// measurements answered from the shared/persistent cache (subset of
    /// `total_measurements` that cost no device time)
    pub cache_hits: usize,
    /// merged device counters across every search-phase measurement
    /// (engine pool workers + serial device)
    pub measure_stats: DeviceStats,
    /// wall seconds the whole offload search took
    pub search_wall_s: f64,
    /// when the report came from the pattern DB's known-pattern fast
    /// path (no search ran), how the pattern was matched
    pub reused_pattern: Option<String>,
    /// whether this search inserted a new learned record into the DB
    pub learned_pattern: bool,
}

impl OffloadReport {
    pub fn speedup(&self) -> f64 {
        self.baseline_s / self.final_s.max(1e-300)
    }

    /// The canonical report JSON — one versioned encoding
    /// (`schema_version` = [`crate::api::SCHEMA_VERSION`]) shared by the
    /// CLI's `--json` output, the serve daemon's `report` payload and
    /// library embedders.
    pub fn to_json(&self) -> Json {
        let gene: String =
            self.best_gene.iter().map(|&b| if b { '1' } else { '0' }).collect();
        let mut j = Json::obj()
            .set("schema_version", crate::api::SCHEMA_VERSION)
            .set("app", self.app.as_str())
            .set("lang", self.lang.name())
            .set("baseline_s", self.baseline_s)
            .set("final_s", self.final_s)
            .set("speedup", self.speedup())
            .set("gene", gene)
            .set("gene_loops", Json::Arr(self.gene_loops.iter().map(|&l| Json::Int(l as i64)).collect()))
            .set(
                "devices",
                Json::Arr(self.devices.iter().map(|d| Json::Str(d.name().to_string())).collect()),
            )
            .set(
                "placement",
                Json::Arr(
                    self.placement
                        .iter()
                        .map(|p| Json::Str(p.map(|t| t.name()).unwrap_or("cpu").to_string()))
                        .collect(),
                ),
            )
            .set("energy_j", self.energy_j)
            .set("power_weight", self.power_weight)
            .set("measurements", self.total_measurements)
            .set("cache_hits", self.cache_hits as i64)
            .set("measure_launches", self.measure_stats.launches as i64)
            .set("search_wall_s", self.search_wall_s)
            .set("gpu_regions", self.final_plan.regions.len())
            .set("gpu_lib_calls", self.final_plan.gpu_calls.len())
            .set("learned_pattern", self.learned_pattern);
        if let Some(how) = &self.reused_pattern {
            j = j.set("pattern_reuse", how.as_str());
        }
        if let Some(fb) = &self.funcblock {
            j = j.set(
                "funcblock_chosen",
                Json::Arr(
                    fb.chosen
                        .iter()
                        .map(|&i| Json::Str(fb.candidates[i].description.clone()))
                        .collect(),
                ),
            );
        }
        if let Some(ga) = &self.ga {
            j = j.set("ga_generations", ga.history.len()).set("ga_evaluations", ga.evaluations);
        }
        j
    }

    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        use crate::util::bench::fmt_time;
        format!(
            "{:<14} [{:<6}] baseline {:>10} → offloaded {:>10}  speedup {:>6.2}x  ({} measurements)",
            self.app,
            self.lang.name(),
            fmt_time(self.baseline_s),
            fmt_time(self.final_s),
            self.speedup(),
            self.total_measurements
        )
    }
}

/// Expand a reduced placement gene (over `gene_loops`, the parallelizable
/// loops left after function-block exclusion) into a full [`ExecPlan`]
/// with the chosen function blocks applied on their destinations —
/// shared by the search path's plan builder and the known-pattern replay
/// path.
fn assemble_plan(
    analysis: &ProgramAnalysis,
    set: &DeviceSet,
    gene_loops: &[LoopId],
    gene: &[bool],
    chosen: &[(Candidate, TargetKind)],
    naive_transfers: bool,
) -> ExecPlan {
    let reduced = set.decode(gene, gene_loops.len());
    let all = analysis.gene_loops();
    let mut full: Vec<Option<TargetKind>> = vec![None; all.len()];
    for (k, id) in gene_loops.iter().enumerate() {
        let pos = all.iter().position(|x| x == id).unwrap();
        full[pos] = reduced[k];
    }
    let mut plan = crate::placement::build_plan(analysis, set, &full, naive_transfers);
    let refs: Vec<(&Candidate, usize)> =
        chosen.iter().map(|(c, t)| (c, set.index_of(*t).unwrap_or(0))).collect();
    funcblock::apply(&mut plan, analysis, &refs);
    plan
}

/// Offload-directive-annotated source for a final plan (library-replaced
/// regions render as offloaded loops too).
fn annotate(prog: &Program, plan: &ExecPlan) -> String {
    let mut directives = analysis::plan_directives(prog, plan);
    for (id, region) in &plan.regions {
        directives.entry(*id).or_insert_with(|| render::LoopDirective {
            offload: true,
            copy_in: region.copy_in.clone(),
            copy_out: region.copy_out.clone(),
            present: vec![],
            dest: plan.devices.get(region.dest).copied(),
        });
    }
    render::render(prog, &directives)
}

/// The coordinator: owns a long-lived device (serial measurement + final
/// verification; its PJRT executable cache persists across trials and
/// applications), the shared measurement cache, and a handle on the
/// (possibly shared) pattern DB. The measurement engines it builds per
/// phase hand pool workers a [`DeviceFactory`] reflecting the backend
/// this device actually runs.
pub struct Coordinator {
    pub cfg: Config,
    db: SharedPatternDb,
    dev: MultiDevice,
    cache: SharedCache,
    compiled: SharedCompiledCache,
}

/// Per-destination device factory for a configuration: the configured
/// `cost` model for the primary target (so explicitly tuned models keep
/// applying), the preset model for every other destination, PJRT gated
/// to the GPU member.
fn factory_for(cfg: &Config, use_pjrt: bool) -> MultiDeviceFactory {
    let devices = cfg.effective_devices();
    MultiDeviceFactory {
        factories: devices
            .iter()
            .map(|&t| DeviceFactory {
                model: if t == cfg.target { cfg.cost.clone() } else { t.cost_model() },
                use_pjrt: use_pjrt && t == TargetKind::Gpu,
            })
            .collect(),
    }
}

impl Coordinator {
    pub fn new(cfg: Config) -> Coordinator {
        let cache = engine::cache_for(&cfg);
        Coordinator::with_cache(cfg, cache)
    }

    /// Coordinator over an existing shared measurement cache — this is how
    /// the adaptive per-target runs and the batch front end's workers
    /// avoid re-measuring patterns another coordinator already tried.
    pub fn with_cache(cfg: Config, cache: SharedCache) -> Coordinator {
        let db = patterndb::shared(PatternDb::open_or_builtin(cfg.pattern_db_path.as_deref()));
        Coordinator::with_shared(cfg, cache, db)
    }

    /// Coordinator over a shared measurement cache *and* a shared pattern
    /// DB — the offload service's workers all learn into, and replay
    /// from, one store.
    pub fn with_shared(cfg: Config, cache: SharedCache, db: SharedPatternDb) -> Coordinator {
        Coordinator::with_caches(cfg, cache, engine::compiled_shared(), db)
    }

    /// Coordinator additionally sharing a compiled-bytecode cache — one
    /// compiled artifact serves every session worker and every repeat
    /// request for the same program.
    pub fn with_caches(
        cfg: Config,
        cache: SharedCache,
        compiled: SharedCompiledCache,
        db: SharedPatternDb,
    ) -> Coordinator {
        let dev = factory_for(&cfg, cfg.use_pjrt).build();
        Coordinator { cfg, db, dev, cache, compiled }
    }

    /// Handle on the shared measurement cache (clone to share).
    pub fn cache(&self) -> SharedCache {
        self.cache.clone()
    }

    /// Handle on the (learning) pattern DB.
    pub fn db(&self) -> SharedPatternDb {
        self.db.clone()
    }

    /// Whether library kernels run through real PJRT artifacts.
    pub fn device_is_pjrt(&self) -> bool {
        self.dev.is_pjrt()
    }

    /// Parse + offload one source string.
    pub fn offload_source(&mut self, code: &str, lang: Lang, name: &str) -> Result<OffloadReport> {
        let prog = frontend::parse(code, lang, name)?;
        self.offload_program(&prog)
    }

    /// The full Fig. 1 flow over a parsed program. Every search-phase
    /// measurement goes through a [`MeasurementEngine`]: batched over the
    /// device worker pool (`cfg.workers`) and memoized in the shared
    /// cross-run cache.
    ///
    /// Before searching, the pattern DB is consulted for a *learned*
    /// pattern of the same (exact fingerprint) or a near-identical
    /// (vector-similar) program: a hit replays the known plan with zero
    /// search measurements — the production fast path of the paper's
    /// service model. After a successful search the winning pattern is
    /// inserted back into the DB (and persisted when
    /// `cfg.pattern_db_path` is set), so the service gets faster with
    /// every application it sees.
    pub fn offload_program(&mut self, prog: &Program) -> Result<OffloadReport> {
        let t_start = std::time::Instant::now();
        let analysis = analysis::analyze(prog);
        // Compile once per program (shared across sessions/requests); the
        // gene is consulted only at region markers, so this one artifact
        // serves every candidate measurement below. A compiler refusal
        // (depth guard) falls back to the tree-walker inside the measurer.
        let compiled = match self.cfg.vm.engine {
            ExecEngine::Bytecode => self.compiled.lock().unwrap().get_or_compile(prog),
            ExecEngine::TreeWalk => None,
        };
        let measurer =
            Measurer::with_compiled(prog, compiled, self.cfg.vm.clone(), self.cfg.tolerance)?;
        let workers = self.cfg.effective_workers();
        let dset = DeviceSet::new(self.cfg.effective_devices())?;
        let mut total_measurements = 0usize;
        let mut cache_hits = 0usize;
        let mut measure_stats = DeviceStats::default();

        // Cache keys must reflect the numerics that actually ran:
        // `with_runtime` silently falls back to simulation when PJRT or
        // the artifacts are unavailable, and a later PJRT-capable run must
        // not reuse times recorded by the fallback (f32 divergence would
        // go undetected). The artifact inventory is folded in too, since
        // library calls fall back per-kernel when an artifact is missing.
        let mut fp_cfg = self.cfg.clone();
        fp_cfg.use_pjrt = self.dev.is_pjrt();
        let artifact_inventory: Vec<String> = self.dev.available_artifacts().to_vec();
        let art_refs: Vec<&str> = artifact_inventory.iter().map(|s| s.as_str()).collect();

        // ---- phase 0: known-pattern fast path ----------------------------
        // The learned fingerprint folds in the same backend/artifact
        // context as the measurement cache, so a plan learned under
        // simulation is never replayed as if it were PJRT-verified.
        let learned_fp = engine::fingerprint(prog, &fp_cfg, "learned", &art_refs);
        if self.cfg.reuse_patterns {
            if let Some(report) =
                self.try_reuse(prog, &analysis, &measurer, &dset, learned_fp, t_start)
            {
                return Ok(report);
            }
        }

        // Engines pool only for simulated backends; hand them a factory
        // reflecting the probed backend, so a PJRT request that fell back
        // to simulation still gets the worker pool instead of a silently
        // serial search.
        let engine_factory = factory_for(&self.cfg, fp_cfg.use_pjrt);

        // ---- phase 1: function blocks (first, per §4.2) ------------------
        let mut fb_report: Option<FuncBlockReport> = None;
        let mut chosen_candidates: Vec<(Candidate, TargetKind)> = Vec::new();
        if self.cfg.funcblock.enabled {
            let candidates = {
                let db = self.db.lock().unwrap();
                funcblock::find_candidates(prog, &analysis, &db, &self.cfg.funcblock)
            };
            if !candidates.is_empty() {
                let fb_plan = funcblock::mask_plan(
                    &analysis,
                    &candidates,
                    &dset,
                    self.plan_naive(),
                );
                // mask slot i means candidates[i], and the candidate list
                // depends on the clone threshold / pattern DB — fold it
                // into the fingerprint so differently-discovered lists
                // never share cache entries
                let cand_context: Vec<String> =
                    candidates.iter().map(|c| c.description.clone()).collect();
                let mut cand_refs: Vec<&str> =
                    cand_context.iter().map(|s| s.as_str()).collect();
                cand_refs.extend(art_refs.iter().copied());
                let mut fb_engine = MeasurementEngine::new(
                    prog,
                    &measurer,
                    engine_factory.clone(),
                    &fb_plan,
                    workers,
                    self.cfg.target,
                    engine::fingerprint(prog, &fp_cfg, "funcblock", &cand_refs),
                    self.cache.clone(),
                    &mut self.dev,
                    self.cfg.power_weight,
                );
                let report = funcblock::trial_combinations(
                    &candidates,
                    &dset,
                    &mut fb_engine,
                    &self.cfg.funcblock,
                );
                total_measurements += report.trials.len();
                cache_hits += fb_engine.cache_hits();
                measure_stats.merge(&fb_engine.stats());
                chosen_candidates = report
                    .chosen
                    .iter()
                    .zip(&report.dests)
                    .map(|(&i, &t)| (report.candidates[i].clone(), t))
                    .collect();
                fb_report = Some(report);
            }
        }

        // ---- phase 2: loop GA on the remaining code ----------------------
        let excluded = self.excluded_loops(&analysis, &chosen_candidates);
        let gene_loops: Vec<LoopId> = analysis
            .gene_loops()
            .into_iter()
            .filter(|id| !excluded.contains(id))
            .collect();

        let naive_transfers = self.plan_naive();
        let build_full_plan = |gene: &[bool]| -> ExecPlan {
            assemble_plan(&analysis, &dset, &gene_loops, gene, &chosen_candidates, naive_transfers)
        };

        // the gene→plan mapping depends on which function blocks were
        // chosen (and where they were placed), so that context is folded
        // into the cache fingerprint
        let fb_context: Vec<String> = chosen_candidates
            .iter()
            .map(|(c, t)| format!("{}@{}", c.description, t.name()))
            .collect();
        let mut fb_context_refs: Vec<&str> = fb_context.iter().map(|s| s.as_str()).collect();
        fb_context_refs.extend(art_refs.iter().copied());
        let mut ga_engine = MeasurementEngine::new(
            prog,
            &measurer,
            engine_factory.clone(),
            &build_full_plan,
            workers,
            self.cfg.target,
            engine::fingerprint(prog, &fp_cfg, "loops", &fb_context_refs),
            self.cache.clone(),
            &mut self.dev,
            self.cfg.power_weight,
        );
        let ga_result: GaResult =
            ga::optimize(dset.gene_len(gene_loops.len()), &self.cfg.ga, &mut ga_engine);
        total_measurements += ga_result.evaluations;
        cache_hits += ga_engine.cache_hits();
        measure_stats.merge(&ga_engine.stats());
        drop(ga_engine);

        // ---- phase 3: final selection + verification ---------------------
        let best_gene = ga_result.best_gene.clone();
        let mut final_plan = build_full_plan(&best_gene);
        // post-GA transfer-optimization pass: attach the order-aware
        // residency plan so the final measurement audits its `present`
        // claims and the rendered directives derive from the same plan
        // the measurement used. (Search trials never carry one — the
        // dynamic residency model already charges hoisted transfers.)
        if !final_plan.naive_transfers {
            final_plan.transfers = Some(crate::transfer::optimize(prog, &final_plan));
        }
        self.dev.reset();
        let final_measurement = measurer.measure(prog, &final_plan, &mut self.dev);
        let final_s = if final_measurement.ok {
            final_measurement.modeled_s
        } else {
            // should not happen (GA keeps the CPU gene) — fall back
            measurer.baseline_modeled_s()
        };

        // ---- directive-annotated source -----------------------------------
        let annotated_source = annotate(prog, &final_plan);

        // persist the measurement cache so the next run starts warm
        if self.cfg.cache_path.is_some() {
            if let Err(e) = self.cache.lock().unwrap().save() {
                eprintln!("warning: measurement cache not saved: {e}");
            }
        }

        // ---- learning: remember the verified pattern ---------------------
        let mut learned_pattern = false;
        if self.cfg.learn_patterns && final_measurement.ok {
            let plan = LearnedPlan {
                fingerprint: learned_fp,
                lang: prog.lang,
                target: self.cfg.target,
                devices: dset.devices().to_vec(),
                gene: best_gene.clone(),
                gene_loops: gene_loops.clone(),
                funcblocks: chosen_candidates
                    .iter()
                    .map(|(c, _)| c.description.clone())
                    .collect(),
                fb_dests: chosen_candidates.iter().map(|(_, t)| *t).collect(),
                baseline_s: measurer.baseline_modeled_s(),
                final_s,
            };
            let description = format!(
                "learned: {} [{}] {:.2}x on {}",
                prog.name,
                prog.lang.name(),
                plan.speedup(),
                dset.name()
            );
            let record =
                PatternRecord::from_learned(description, char_vector_program(prog), plan);
            let mut db = self.db.lock().unwrap();
            learned_pattern = db.insert_learned(record);
            if learned_pattern {
                if let Some(p) = &self.cfg.pattern_db_path {
                    if let Err(e) = db.flush(p) {
                        eprintln!("warning: pattern DB not saved: {e}");
                    }
                }
            }
        }

        let placement = dset.decode(&best_gene, gene_loops.len());
        Ok(OffloadReport {
            app: prog.name.clone(),
            lang: prog.lang,
            baseline_s: measurer.baseline_modeled_s(),
            final_s,
            funcblock: fb_report,
            ga: Some(ga_result),
            gene_loops,
            best_gene,
            devices: dset.devices().to_vec(),
            placement,
            energy_j: final_measurement.energy_j,
            power_weight: self.cfg.power_weight,
            final_plan,
            final_measurement,
            annotated_source,
            total_measurements,
            cache_hits,
            measure_stats,
            search_wall_s: t_start.elapsed().as_secs_f64(),
            reused_pattern: None,
            learned_pattern,
        })
    }

    /// The known-pattern fast path: find a learned plan for this exact
    /// program (fingerprint) or a near-identical one (whole-program
    /// characteristic-vector similarity + identical modeled baseline),
    /// rebuild it against a fresh analysis, and re-verify it once on the
    /// coordinator's device. Returns `None` — falling back to the full
    /// search — whenever any step fails to line up: the replay is an
    /// optimization, never a source of unverified answers.
    ///
    /// The returned report performs **zero search measurements**:
    /// `total_measurements`, `cache_hits` and `measure_stats` are all
    /// zero (the single verification run is deploy-time safety, the same
    /// final check the search path does not count either).
    fn try_reuse(
        &mut self,
        prog: &Program,
        analysis: &ProgramAnalysis,
        measurer: &Measurer,
        dset: &DeviceSet,
        learned_fp: u64,
        t_start: std::time::Instant,
    ) -> Option<OffloadReport> {
        // snapshot the matching plan under the lock, then measure without
        // holding it (other service workers keep going)
        let (plan_rec, how) = {
            let mut db = self.db.lock().unwrap();
            if db.learned_len() == 0 {
                return None;
            }
            if let Some(r) = db.lookup_learned_set(learned_fp, dset.devices()) {
                let how = format!("exact ({})", r.key);
                (r.learned.clone().unwrap(), how)
            } else {
                let v = char_vector_program(prog);
                // the similarity gate is per-language: the vector is
                // computed on the language-independent IR, so the same
                // app in another language scores 1.0 — and must still
                // run its own search rather than replay a foreign record
                let (r, score) = db.lookup_learned_similar(
                    &v,
                    prog.lang,
                    dset.devices(),
                    self.cfg.reuse_similarity,
                )?;
                let p = r.learned.clone().unwrap();
                // a near-identical program must also have an identical
                // modeled baseline — structure AND workload must agree
                let base = measurer.baseline_modeled_s();
                if (p.baseline_s - base).abs() > 1e-9 * base.abs().max(1e-300) {
                    return None;
                }
                let how = format!("similar (score {score:.4}, {})", r.key);
                (p, how)
            }
        };
        // the learned gene only decodes against the set it was searched
        // with (lookup keys guarantee this; re-check defensively)
        if plan_rec.devices != dset.devices() {
            return None;
        }

        // rebuild the chosen function blocks from a fresh candidate scan
        let mut chosen: Vec<(Candidate, TargetKind)> = Vec::new();
        if !plan_rec.funcblocks.is_empty() {
            if !self.cfg.funcblock.enabled {
                return None;
            }
            let candidates = {
                let db = self.db.lock().unwrap();
                funcblock::find_candidates(prog, analysis, &db, &self.cfg.funcblock)
            };
            for (want, dest) in plan_rec.funcblocks.iter().zip(&plan_rec.fb_dests) {
                match candidates.iter().find(|c| &c.description == want) {
                    Some(c) => chosen.push((c.clone(), *dest)),
                    None => return None, // pattern no longer applies here
                }
            }
        }
        let excluded = self.excluded_loops(analysis, &chosen);
        let gene_loops: Vec<LoopId> =
            analysis.gene_loops().into_iter().filter(|id| !excluded.contains(id)).collect();
        if gene_loops != plan_rec.gene_loops
            || plan_rec.gene.len() != dset.gene_len(gene_loops.len())
        {
            return None;
        }
        let mut final_plan = assemble_plan(
            analysis,
            dset,
            &gene_loops,
            &plan_rec.gene,
            &chosen,
            self.plan_naive(),
        );
        if !final_plan.naive_transfers {
            final_plan.transfers = Some(crate::transfer::optimize(prog, &final_plan));
        }

        // re-verify the replayed plan (PCAST results check) — a stale or
        // mis-matched pattern falls back to the full search
        self.dev.reset();
        let final_measurement = measurer.measure(prog, &final_plan, &mut self.dev);
        if !final_measurement.ok {
            return None;
        }
        let annotated_source = annotate(prog, &final_plan);
        // the replay applied the learned function blocks — report them
        // (no trials ran, so the trial list is empty)
        let funcblock = if chosen.is_empty() {
            None
        } else {
            Some(FuncBlockReport {
                chosen: (0..chosen.len()).collect(),
                dests: chosen.iter().map(|(_, t)| *t).collect(),
                candidates: chosen.into_iter().map(|(c, _)| c).collect(),
                best: final_measurement.clone(),
                trials: Vec::new(),
            })
        };
        let placement = dset.decode(&plan_rec.gene, gene_loops.len());
        Some(OffloadReport {
            app: prog.name.clone(),
            lang: prog.lang,
            baseline_s: measurer.baseline_modeled_s(),
            final_s: final_measurement.modeled_s,
            funcblock,
            ga: None,
            gene_loops,
            best_gene: plan_rec.gene,
            devices: dset.devices().to_vec(),
            placement,
            energy_j: final_measurement.energy_j,
            power_weight: self.cfg.power_weight,
            final_plan,
            final_measurement,
            annotated_source,
            total_measurements: 0,
            cache_hits: 0,
            measure_stats: DeviceStats::default(),
            search_wall_s: t_start.elapsed().as_secs_f64(),
            reused_pattern: Some(how),
            learned_pattern: false,
        })
    }

    /// Plans are built naive (per-region transfer accounting) for the
    /// [37] ablation *and* when the transfer-optimization pass is off —
    /// without the pass there is nothing to hoist, so the cost model
    /// must charge the un-hoisted per-region copies.
    fn plan_naive(&self) -> bool {
        self.cfg.naive_transfers || self.cfg.no_transfer_opt
    }

    /// Loops the GA must not touch: inside a clone-replaced nest, or an
    /// ancestor of one (offloading an ancestor would re-enter the replaced
    /// region on the device).
    fn excluded_loops(
        &self,
        analysis: &ProgramAnalysis,
        chosen: &[(Candidate, TargetKind)],
    ) -> HashSet<LoopId> {
        let mut excluded = HashSet::new();
        for (c, _) in chosen {
            excluded.extend(c.swallowed_loops(analysis));
            if let funcblock::CandidateKind::CloneNest { root, .. } = &c.kind {
                let mut anc = analysis.loops[*root].parent;
                while let Some(a) = anc {
                    excluded.insert(a);
                    anc = analysis.loops[a].parent;
                }
            }
        }
        excluded
    }
}

// ---------------------------------------------------------------------------
// batch / adaptive front ends — moved to the versioned API layer
// ---------------------------------------------------------------------------
//
// The free functions that used to live here (`offload_adaptive`,
// `offload_batch` + `BatchRequest`, `offload_workload`) are now methods
// of [`crate::api::OffloadSession`] consuming the one typed
// [`crate::api::OffloadRequest`] — the same request type the CLI, the
// serve daemon and library embedders construct. This module keeps only
// the coordinator itself and its report.

/// Markdown summary table over several reports (E3-style output).
pub fn markdown_summary(reports: &[OffloadReport]) -> String {
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            vec![
                r.app.clone(),
                r.lang.name().to_string(),
                format!("{:.3}", r.baseline_s * 1e3),
                format!("{:.3}", r.final_s * 1e3),
                format!("{:.2}x", r.speedup()),
                format!("{}", r.total_measurements),
            ]
        })
        .collect();
    crate::util::bench::markdown_table(
        &["app", "lang", "CPU ms", "offloaded ms", "speedup", "measurements"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{offload_workload, OffloadRequest, OffloadSession};

    fn fast_cfg() -> Config {
        Config::fast_sim()
    }

    #[test]
    fn mm_offload_finds_clone_replacement_and_speedup() {
        let r = offload_workload("mm", Lang::C, fast_cfg()).unwrap();
        assert!(r.final_measurement.ok);
        assert!(r.speedup() > 2.0, "speedup {}", r.speedup());
        // the hand-written matmul nest must be library-replaced
        let fb = r.funcblock.as_ref().unwrap();
        assert!(!fb.chosen.is_empty(), "clone replacement should win");
        assert!(
            r.final_plan
                .regions
                .values()
                .any(|g| matches!(g.exec, crate::vm::RegionExec::Library { .. })),
            "final plan should contain a library region"
        );
    }

    #[test]
    fn smallloops_stays_on_cpu() {
        let r = offload_workload("smallloops", Lang::C, fast_cfg()).unwrap();
        // GA should learn that offloading tiny loops hurts
        assert!(
            r.best_gene.iter().all(|&b| !b),
            "small loops must stay on CPU: {:?}",
            r.best_gene
        );
        assert!((r.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn same_pattern_found_across_languages() {
        // E7: semantically identical apps → same offload decisions
        let mut speedups = Vec::new();
        for lang in Lang::all() {
            let r = offload_workload("blackscholes", lang, fast_cfg()).unwrap();
            assert!(r.final_measurement.ok, "{lang}: {:?}", r.final_measurement.failure);
            speedups.push((lang, r.best_gene.clone(), r.speedup()));
        }
        for w in speedups.windows(2) {
            assert_eq!(w[0].1, w[1].1, "{} vs {} gene mismatch", w[0].0, w[1].0);
            assert!((w[0].2 - w[1].2).abs() < 1e-9, "speedups differ");
        }
    }

    #[test]
    fn fourier_uses_name_matched_library() {
        let r = offload_workload("fourier", Lang::Java, fast_cfg()).unwrap();
        assert!(r.final_plan.gpu_calls.contains("dft"), "dft should be GPU-replaced");
        assert!(r.speedup() > 1.5, "speedup {}", r.speedup());
    }

    #[test]
    fn annotated_source_contains_directives() {
        let r = offload_workload("blackscholes", Lang::C, fast_cfg()).unwrap();
        assert!(
            r.annotated_source.contains("#pragma acc"),
            "annotated source should carry OpenACC directives:\n{}",
            r.annotated_source
        );
        let rp = offload_workload("blackscholes", Lang::Python, fast_cfg()).unwrap();
        assert!(rp.annotated_source.contains("# [pycuda]"));
    }

    #[test]
    fn adaptive_target_selection_picks_many_core_for_small_loops() {
        // small parallel loops: many-core (no transfers, cheap entry) must
        // beat the GPU; heavy compute prefers the GPU
        let mut session = OffloadSession::new(fast_cfg());
        let req = OffloadRequest::workload("smallloops", Lang::C).build().unwrap();
        let r = session.offload_adaptive(&req, &crate::device::TargetKind::all()).unwrap();
        assert_eq!(r.per_target.len(), 3);
        // every target at least matches CPU (GA keeps the all-zero gene)
        for (t, rep) in &r.per_target {
            assert!(rep.speedup() >= 0.999, "{t}: {}", rep.speedup());
        }
        let mut session = OffloadSession::new(fast_cfg());
        let heavy = OffloadRequest::workload("blackscholes", Lang::C).build().unwrap();
        let r2 = session.offload_adaptive(&heavy, &crate::device::TargetKind::all()).unwrap();
        // on the heavy elementwise app the accelerators must beat many-core
        let get = |t: crate::device::TargetKind| {
            r2.per_target.iter().find(|(x, _)| *x == t).unwrap().1.final_s
        };
        assert!(
            get(crate::device::TargetKind::Gpu) < get(crate::device::TargetKind::ManyCore),
            "GPU should win on heavy elementwise work"
        );
    }

    #[test]
    fn report_json_is_well_formed() {
        let r = offload_workload("smallloops", Lang::Python, fast_cfg()).unwrap();
        let s = r.to_json().to_string();
        assert!(s.contains("\"app\":\"smallloops\""));
        assert!(s.contains("\"speedup\":"));
        assert!(s.contains("\"learned_pattern\":true"));
    }

    #[test]
    fn second_identical_request_replays_learned_pattern() {
        let mut c = Coordinator::new(fast_cfg());
        let src = crate::workloads::get("mm", Lang::C).unwrap();
        let r1 = c.offload_source(src.code, Lang::C, "mm").unwrap();
        assert!(r1.reused_pattern.is_none(), "first request must search");
        assert!(r1.learned_pattern, "successful search must learn");
        assert!(r1.total_measurements > 0);

        let r2 = c.offload_source(src.code, Lang::C, "mm").unwrap();
        assert!(r2.reused_pattern.is_some(), "repeat request must hit the pattern DB");
        assert!(r2.reused_pattern.as_ref().unwrap().starts_with("exact"));
        assert_eq!(r2.total_measurements, 0, "replay performs zero search measurements");
        assert_eq!(r2.cache_hits, 0);
        assert_eq!(r2.measure_stats.launches, 0);
        assert_eq!(r2.best_gene, r1.best_gene, "same plan as the search found");
        assert_eq!(r2.gene_loops, r1.gene_loops);
        assert_eq!(r2.final_s, r1.final_s);
        assert_eq!(r2.final_plan.gpu_calls, r1.final_plan.gpu_calls);
        assert_eq!(r2.annotated_source, r1.annotated_source);
        assert!(!r2.learned_pattern, "an identical replay re-learns nothing");
        // the replay reports the same chosen function blocks the search found
        let chosen_descs = |r: &OffloadReport| -> Vec<String> {
            let fb = r.funcblock.as_ref().expect("mm has function blocks");
            fb.chosen.iter().map(|&i| fb.candidates[i].description.clone()).collect()
        };
        assert_eq!(chosen_descs(&r1), chosen_descs(&r2));
    }

    #[test]
    fn renamed_variables_replay_via_similarity() {
        // alpha-renaming keeps the characteristic vector and the modeled
        // baseline identical but changes the program fingerprint — the
        // similar-pattern path must pick it up
        let src = r#"void main() {
            int n = 512;
            double x[n]; double y[n];
            seed_fill(x, 3);
            for (int i = 0; i < n; i++) { y[i] = x[i] * 2.0 + 1.0; }
            double s = 0.0;
            for (int i = 0; i < n; i++) { s += y[i] + x[i]; }
            printf("%f\n", s);
        }"#;
        let renamed = src.replace('x', "u").replace('y', "w");
        assert_ne!(src, renamed);
        let mut c = Coordinator::new(fast_cfg());
        let r1 = c.offload_source(src, Lang::C, "app1").unwrap();
        assert!(r1.learned_pattern);
        let r2 = c.offload_source(&renamed, Lang::C, "app2").unwrap();
        assert!(
            r2.reused_pattern.as_deref().is_some_and(|h| h.starts_with("similar")),
            "renamed program should replay the learned pattern, got {:?}",
            r2.reused_pattern
        );
        assert_eq!(r2.total_measurements, 0);
        assert_eq!(r2.best_gene, r1.best_gene);
        assert_eq!(r2.final_s, r1.final_s);
    }

    #[test]
    fn identical_program_in_another_language_never_replays() {
        // the same app in two languages lowers to the same IR (identical
        // characteristic vector AND identical modeled baseline), so this
        // is exactly the cross-language collision the per-language
        // learned keys must prevent
        let mut c = Coordinator::new(fast_cfg());
        let js = crate::workloads::get("smallloops", Lang::JavaScript).unwrap();
        let r1 = c.offload_source(js.code, Lang::JavaScript, "smallloops").unwrap();
        assert!(r1.learned_pattern, "JS search must learn");
        let r2 = c.offload_source(js.code, Lang::JavaScript, "smallloops").unwrap();
        assert!(r2.reused_pattern.is_some(), "same-language repeat replays");
        let py = crate::workloads::get("smallloops", Lang::Python).unwrap();
        let r3 = c.offload_source(py.code, Lang::Python, "smallloops").unwrap();
        assert!(
            r3.reused_pattern.is_none(),
            "a different-language twin must run its own search, got {:?}",
            r3.reused_pattern
        );
        assert!(r3.total_measurements > 0);
        // same plan found independently — the method is language-agnostic
        assert_eq!(r3.best_gene, r1.best_gene);
    }

    #[test]
    fn reuse_and_learning_can_be_disabled() {
        let mut cfg = fast_cfg();
        cfg.learn_patterns = false;
        let mut c = Coordinator::new(cfg);
        let src = crate::workloads::get("smallloops", Lang::C).unwrap();
        let r1 = c.offload_source(src.code, Lang::C, "smallloops").unwrap();
        assert!(!r1.learned_pattern);
        let r2 = c.offload_source(src.code, Lang::C, "smallloops").unwrap();
        assert!(r2.reused_pattern.is_none(), "nothing learned, nothing to reuse");
        assert!(r2.total_measurements > 0);

        let mut cfg = fast_cfg();
        cfg.reuse_patterns = false;
        let mut c = Coordinator::new(cfg);
        let r1 = c.offload_source(src.code, Lang::C, "smallloops").unwrap();
        assert!(r1.learned_pattern, "learning still on");
        let r2 = c.offload_source(src.code, Lang::C, "smallloops").unwrap();
        assert!(r2.reused_pattern.is_none(), "reuse disabled: full search again");
        assert!(r2.total_measurements > 0);
    }

    #[test]
    fn pattern_db_persists_across_coordinators() {
        let tmp = std::env::temp_dir()
            .join(format!("envadapt_coord_db_{}.txt", std::process::id()));
        let _ = std::fs::remove_file(&tmp);
        let mut cfg = fast_cfg();
        cfg.pattern_db_path = Some(tmp.clone());
        let src = crate::workloads::get("fourier", Lang::Java).unwrap();
        let r1 = {
            let mut c = Coordinator::new(cfg.clone());
            c.offload_source(src.code, Lang::Java, "fourier").unwrap()
        };
        assert!(r1.learned_pattern);
        assert!(tmp.exists(), "learned pattern must be persisted");
        // a brand-new coordinator (fresh process in real life) replays it
        let mut c2 = Coordinator::new(cfg);
        let r2 = c2.offload_source(src.code, Lang::Java, "fourier").unwrap();
        assert!(r2.reused_pattern.is_some(), "persisted pattern must replay");
        assert_eq!(r2.total_measurements, 0);
        assert_eq!(r2.best_gene, r1.best_gene);
        assert_eq!(r2.final_s, r1.final_s);
        std::fs::remove_file(tmp).ok();
    }
}
