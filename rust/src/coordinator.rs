//! The environment-adaptive offloading coordinator — Fig. 1's flow,
//! end-to-end (the paper's system contribution).
//!
//! For one application in any supported language:
//!
//! 1. **Code analysis** — parse to the language-independent IR, build the
//!    loop/variable/function-block tables (`frontend`, `analysis`).
//! 2. **Function-block offload trial** (§4.2, tried *first* because
//!    algorithm-tuned blocks beat per-loop parallelization): name-match +
//!    clone-similarity candidates against the pattern DB, measured
//!    individually and in combination.
//! 3. **Loop-statement offload trial** — GA over the remaining
//!    parallelizable loops (function-block-replaced nests are excluded,
//!    §4.2: 機能ブロック部分を抜いたコードに対して試行), each gene measured
//!    in the verification environment with transfer-hoisting applied.
//! 4. **Final pattern selection** — fastest correct candidate wins; the
//!    report carries per-language directive-annotated source (OpenACC /
//!    PyCUDA / parallel-stream) plus every number the benches need.

use crate::analysis::{self, ProgramAnalysis};
use crate::config::Config;
use crate::device::{DeviceFactory, DeviceStats, GpuDevice};
use crate::engine::{self, MeasurementEngine, SharedCache};
use crate::frontend::{self, render};
use crate::funcblock::{self, Candidate, FuncBlockReport};
use crate::ga::{self, GaResult};
use crate::ir::{Lang, LoopId, Program};
use crate::measure::{Measurement, Measurer};
use crate::patterndb::PatternDb;
use crate::util::json::Json;
use crate::vm::ExecPlan;
use anyhow::Result;
use std::collections::HashSet;

/// Everything the coordinator learned about one application.
#[derive(Debug, Clone)]
pub struct OffloadReport {
    pub app: String,
    pub lang: Lang,
    /// CPU-only modeled seconds
    pub baseline_s: f64,
    /// best offload pattern's modeled seconds
    pub final_s: f64,
    pub funcblock: Option<FuncBlockReport>,
    pub ga: Option<GaResult>,
    /// loop ids the gene indexes (after function-block exclusion)
    pub gene_loops: Vec<LoopId>,
    pub best_gene: Vec<bool>,
    pub final_plan: ExecPlan,
    /// final verification measurement
    pub final_measurement: Measurement,
    /// offload-directive-annotated source in the app's own language
    pub annotated_source: String,
    /// total distinct measurements spent (func-block trials + GA)
    pub total_measurements: usize,
    /// measurements answered from the shared/persistent cache (subset of
    /// `total_measurements` that cost no device time)
    pub cache_hits: usize,
    /// merged device counters across every search-phase measurement
    /// (engine pool workers + serial device)
    pub measure_stats: DeviceStats,
    /// wall seconds the whole offload search took
    pub search_wall_s: f64,
}

impl OffloadReport {
    pub fn speedup(&self) -> f64 {
        self.baseline_s / self.final_s.max(1e-300)
    }

    /// JSON rendering for logs / EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        let gene: String =
            self.best_gene.iter().map(|&b| if b { '1' } else { '0' }).collect();
        let mut j = Json::obj()
            .set("app", self.app.as_str())
            .set("lang", self.lang.name())
            .set("baseline_s", self.baseline_s)
            .set("final_s", self.final_s)
            .set("speedup", self.speedup())
            .set("gene", gene)
            .set("gene_loops", Json::Arr(self.gene_loops.iter().map(|&l| Json::Int(l as i64)).collect()))
            .set("measurements", self.total_measurements)
            .set("cache_hits", self.cache_hits as i64)
            .set("measure_launches", self.measure_stats.launches as i64)
            .set("search_wall_s", self.search_wall_s)
            .set("gpu_regions", self.final_plan.regions.len())
            .set("gpu_lib_calls", self.final_plan.gpu_calls.len());
        if let Some(fb) = &self.funcblock {
            j = j.set(
                "funcblock_chosen",
                Json::Arr(
                    fb.chosen
                        .iter()
                        .map(|&i| Json::Str(fb.candidates[i].description.clone()))
                        .collect(),
                ),
            );
        }
        if let Some(ga) = &self.ga {
            j = j.set("ga_generations", ga.history.len()).set("ga_evaluations", ga.evaluations);
        }
        j
    }

    /// One-line summary for CLI output.
    pub fn summary(&self) -> String {
        use crate::util::bench::fmt_time;
        format!(
            "{:<14} [{:<6}] baseline {:>10} → offloaded {:>10}  speedup {:>6.2}x  ({} measurements)",
            self.app,
            self.lang.name(),
            fmt_time(self.baseline_s),
            fmt_time(self.final_s),
            self.speedup(),
            self.total_measurements
        )
    }
}

/// The coordinator: owns a long-lived device (serial measurement + final
/// verification; its PJRT executable cache persists across trials and
/// applications), the shared measurement cache, and the pattern DB. The
/// measurement engines it builds per phase hand pool workers a
/// [`DeviceFactory`] reflecting the backend this device actually runs.
pub struct Coordinator {
    pub cfg: Config,
    pub db: PatternDb,
    dev: GpuDevice,
    cache: SharedCache,
}

impl Coordinator {
    pub fn new(cfg: Config) -> Coordinator {
        let cache = engine::cache_for(&cfg);
        Coordinator::with_cache(cfg, cache)
    }

    /// Coordinator over an existing shared measurement cache — this is how
    /// the adaptive per-target runs and the batch front end's workers
    /// avoid re-measuring patterns another coordinator already tried.
    pub fn with_cache(cfg: Config, cache: SharedCache) -> Coordinator {
        let dev = DeviceFactory::new(cfg.cost.clone(), cfg.use_pjrt).build();
        Coordinator { cfg, db: PatternDb::builtin(), dev, cache }
    }

    /// Handle on the shared measurement cache (clone to share).
    pub fn cache(&self) -> SharedCache {
        self.cache.clone()
    }

    /// Whether library kernels run through real PJRT artifacts.
    pub fn device_is_pjrt(&self) -> bool {
        self.dev.is_pjrt()
    }

    /// Parse + offload one source string.
    pub fn offload_source(&mut self, code: &str, lang: Lang, name: &str) -> Result<OffloadReport> {
        let prog = frontend::parse(code, lang, name)?;
        self.offload_program(&prog)
    }

    /// The full Fig. 1 flow over a parsed program. Every search-phase
    /// measurement goes through a [`MeasurementEngine`]: batched over the
    /// device worker pool (`cfg.workers`) and memoized in the shared
    /// cross-run cache.
    pub fn offload_program(&mut self, prog: &Program) -> Result<OffloadReport> {
        let t_start = std::time::Instant::now();
        let analysis = analysis::analyze(prog);
        let measurer = Measurer::new(prog, self.cfg.vm.clone(), self.cfg.tolerance)?;
        let workers = self.cfg.effective_workers();
        let mut total_measurements = 0usize;
        let mut cache_hits = 0usize;
        let mut measure_stats = DeviceStats::default();

        // Cache keys must reflect the numerics that actually ran:
        // `with_runtime` silently falls back to simulation when PJRT or
        // the artifacts are unavailable, and a later PJRT-capable run must
        // not reuse times recorded by the fallback (f32 divergence would
        // go undetected). The artifact inventory is folded in too, since
        // library calls fall back per-kernel when an artifact is missing.
        let mut fp_cfg = self.cfg.clone();
        fp_cfg.use_pjrt = self.dev.is_pjrt();
        let artifact_inventory: Vec<String> = self.dev.available_artifacts().to_vec();
        let art_refs: Vec<&str> = artifact_inventory.iter().map(|s| s.as_str()).collect();
        // Engines pool only for simulated backends; hand them a factory
        // reflecting the probed backend, so a PJRT request that fell back
        // to simulation still gets the worker pool instead of a silently
        // serial search.
        let engine_factory = DeviceFactory::new(self.cfg.cost.clone(), fp_cfg.use_pjrt);

        // ---- phase 1: function blocks (first, per §4.2) ------------------
        let mut fb_report: Option<FuncBlockReport> = None;
        let mut chosen_candidates: Vec<Candidate> = Vec::new();
        if self.cfg.funcblock.enabled {
            let candidates =
                funcblock::find_candidates(prog, &analysis, &self.db, &self.cfg.funcblock);
            if !candidates.is_empty() {
                let fb_plan =
                    funcblock::mask_plan(&analysis, &candidates, self.cfg.naive_transfers);
                // mask bit i means candidates[i], and the candidate list
                // depends on the clone threshold / pattern DB — fold it
                // into the fingerprint so differently-discovered lists
                // never share cache entries
                let cand_context: Vec<String> =
                    candidates.iter().map(|c| c.description.clone()).collect();
                let mut cand_refs: Vec<&str> =
                    cand_context.iter().map(|s| s.as_str()).collect();
                cand_refs.extend(art_refs.iter().copied());
                let mut fb_engine = MeasurementEngine::new(
                    prog,
                    &measurer,
                    engine_factory.clone(),
                    &fb_plan,
                    workers,
                    self.cfg.target,
                    engine::fingerprint(prog, &fp_cfg, "funcblock", &cand_refs),
                    self.cache.clone(),
                    &mut self.dev,
                );
                let report =
                    funcblock::trial_combinations(&candidates, &mut fb_engine, &self.cfg.funcblock);
                total_measurements += report.trials.len();
                cache_hits += fb_engine.cache_hits();
                measure_stats.merge(&fb_engine.stats());
                chosen_candidates =
                    report.chosen.iter().map(|&i| report.candidates[i].clone()).collect();
                fb_report = Some(report);
            }
        }

        // ---- phase 2: loop GA on the remaining code ----------------------
        let excluded = self.excluded_loops(&analysis, &chosen_candidates);
        let gene_loops: Vec<LoopId> = analysis
            .gene_loops()
            .into_iter()
            .filter(|id| !excluded.contains(id))
            .collect();

        let naive_transfers = self.cfg.naive_transfers;
        let chosen_refs: Vec<&Candidate> = chosen_candidates.iter().collect();
        let build_full_plan = |gene: &[bool]| -> ExecPlan {
            // expand the reduced gene back over all parallelizable loops
            let all = analysis.gene_loops();
            let mut full = vec![false; all.len()];
            for (k, id) in gene_loops.iter().enumerate() {
                let pos = all.iter().position(|x| x == id).unwrap();
                full[pos] = gene[k];
            }
            let mut plan = analysis::build_plan(&analysis, &full, naive_transfers);
            funcblock::apply(&mut plan, &analysis, &chosen_refs);
            plan
        };

        // the gene→plan mapping depends on which function blocks were
        // chosen, so that context is folded into the cache fingerprint
        let fb_context: Vec<String> =
            chosen_candidates.iter().map(|c| c.description.clone()).collect();
        let mut fb_context_refs: Vec<&str> = fb_context.iter().map(|s| s.as_str()).collect();
        fb_context_refs.extend(art_refs.iter().copied());
        let mut ga_engine = MeasurementEngine::new(
            prog,
            &measurer,
            engine_factory.clone(),
            &build_full_plan,
            workers,
            self.cfg.target,
            engine::fingerprint(prog, &fp_cfg, "loops", &fb_context_refs),
            self.cache.clone(),
            &mut self.dev,
        );
        let ga_result: GaResult = ga::optimize(gene_loops.len(), &self.cfg.ga, &mut ga_engine);
        total_measurements += ga_result.evaluations;
        cache_hits += ga_engine.cache_hits();
        measure_stats.merge(&ga_engine.stats());
        drop(ga_engine);

        // ---- phase 3: final selection + verification ---------------------
        let best_gene = ga_result.best_gene.clone();
        let final_plan = build_full_plan(&best_gene);
        self.dev.reset();
        let final_measurement = measurer.measure(prog, &final_plan, &mut self.dev);
        let final_s = if final_measurement.ok {
            final_measurement.modeled_s
        } else {
            // should not happen (GA keeps the CPU gene) — fall back
            measurer.baseline_modeled_s()
        };

        // ---- directive-annotated source -----------------------------------
        let mut directives = analysis::plan_directives(&analysis, &final_plan);
        // library-replaced regions render as offloaded loops too
        for (id, region) in &final_plan.regions {
            directives.entry(*id).or_insert_with(|| render::LoopDirective {
                offload: true,
                copy_in: region.copy_in.clone(),
                copy_out: region.copy_out.clone(),
                present: vec![],
            });
        }
        let annotated_source = render::render(prog, &directives);

        // persist the measurement cache so the next run starts warm
        if self.cfg.cache_path.is_some() {
            if let Err(e) = self.cache.lock().unwrap().save() {
                eprintln!("warning: measurement cache not saved: {e}");
            }
        }

        Ok(OffloadReport {
            app: prog.name.clone(),
            lang: prog.lang,
            baseline_s: measurer.baseline_modeled_s(),
            final_s,
            funcblock: fb_report,
            ga: Some(ga_result),
            gene_loops,
            best_gene,
            final_plan,
            final_measurement,
            annotated_source,
            total_measurements,
            cache_hits,
            measure_stats,
            search_wall_s: t_start.elapsed().as_secs_f64(),
        })
    }

    /// Loops the GA must not touch: inside a clone-replaced nest, or an
    /// ancestor of one (offloading an ancestor would re-enter the replaced
    /// region on the device).
    fn excluded_loops(
        &self,
        analysis: &ProgramAnalysis,
        chosen: &[Candidate],
    ) -> HashSet<LoopId> {
        let mut excluded = HashSet::new();
        for c in chosen {
            excluded.extend(c.swallowed_loops(analysis));
            if let funcblock::CandidateKind::CloneNest { root, .. } = &c.kind {
                let mut anc = analysis.loops[*root].parent;
                while let Some(a) = anc {
                    excluded.insert(a);
                    anc = analysis.loops[a].parent;
                }
            }
        }
        excluded
    }
}

// ---------------------------------------------------------------------------
// environment-adaptive target selection (GPU / many-core / FPGA)
// ---------------------------------------------------------------------------

/// Result of trying every migration target the environment offers
/// (the outer loop of the environment-adaptive concept: the same code is
/// converted for whatever accelerator the deployment environment has, and
/// the best-performing target is selected).
#[derive(Debug)]
pub struct AdaptiveReport {
    pub per_target: Vec<(crate::device::TargetKind, OffloadReport)>,
    pub chosen: crate::device::TargetKind,
}

impl AdaptiveReport {
    pub fn chosen_report(&self) -> &OffloadReport {
        &self.per_target.iter().find(|(t, _)| *t == self.chosen).unwrap().1
    }
}

/// Offload `code` against every target in `targets`, returning all reports
/// and the fastest target. PJRT artifacts are used for the GPU target
/// (when `cfg.use_pjrt`); other targets use their cost models with CPU
/// reference numerics (the substitution DESIGN.md documents).
pub fn offload_adaptive(
    code: &str,
    lang: Lang,
    name: &str,
    cfg: &Config,
    targets: &[crate::device::TargetKind],
) -> Result<AdaptiveReport> {
    anyhow::ensure!(!targets.is_empty(), "need at least one target");
    // one measurement cache across all targets: re-running a target (or
    // the whole adaptive search) answers known patterns without a device
    let cache = engine::cache_for(cfg);
    let mut per_target = Vec::new();
    for &t in targets {
        let mut tcfg = cfg.clone();
        tcfg.target = t;
        tcfg.cost = t.cost_model();
        tcfg.use_pjrt = cfg.use_pjrt && t == crate::device::TargetKind::Gpu;
        let mut c = Coordinator::with_cache(tcfg, cache.clone());
        per_target.push((t, c.offload_source(code, lang, name)?));
    }
    let chosen = per_target
        .iter()
        .min_by(|a, b| a.1.final_s.partial_cmp(&b.1.final_s).unwrap())
        .unwrap()
        .0;
    Ok(AdaptiveReport { per_target, chosen })
}

// ---------------------------------------------------------------------------
// batch front end (the "application use request" loop of §4.2)
// ---------------------------------------------------------------------------

/// One offload request.
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub name: String,
    pub lang: Lang,
    pub code: String,
}

impl BatchRequest {
    pub fn workload(app: &str, lang: Lang) -> Option<BatchRequest> {
        let s = crate::workloads::get(app, lang)?;
        Some(BatchRequest { name: app.to_string(), lang, code: s.code.to_string() })
    }
}

/// Serve a batch of offload requests over `workers` OS threads, each with
/// its own coordinator (PJRT clients are not `Send`, so every worker owns
/// its device; executable caches are per-worker). All workers share one
/// measurement cache, so repeated requests for the same program answer
/// from memory. Result order matches request order.
pub fn offload_batch(
    requests: &[BatchRequest],
    workers: usize,
    cfg: &Config,
) -> Vec<Result<OffloadReport>> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let workers = workers.clamp(1, requests.len().max(1));
    // split the measurement-worker budget across request workers so the
    // two pool levels don't multiply into workers × cfg.workers threads
    let mut wcfg = cfg.clone();
    wcfg.workers = (cfg.effective_workers() / workers).max(1);
    let cache = engine::cache_for(cfg);
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<Result<OffloadReport>>>> =
        Mutex::new((0..requests.len()).map(|_| None).collect());
    let wcfg = &wcfg;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let cache = cache.clone();
            let next = &next;
            let results = &results;
            scope.spawn(move || {
                let mut c = Coordinator::with_cache(wcfg.clone(), cache);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= requests.len() {
                        break;
                    }
                    let r = &requests[i];
                    let out = c.offload_source(&r.code, r.lang, &r.name);
                    results.lock().unwrap()[i] = Some(out);
                }
            });
        }
    });
    results.into_inner().unwrap().into_iter().map(|o| o.expect("worker filled slot")).collect()
}

/// Convenience: offload one workload app in one language with a config.
pub fn offload_workload(app: &str, lang: Lang, cfg: Config) -> Result<OffloadReport> {
    let src = crate::workloads::get(app, lang)
        .ok_or_else(|| anyhow::anyhow!("unknown workload `{app}`"))?;
    let mut c = Coordinator::new(cfg);
    c.offload_source(src.code, lang, app)
}

/// Markdown summary table over several reports (E3-style output).
pub fn markdown_summary(reports: &[OffloadReport]) -> String {
    let rows: Vec<Vec<String>> = reports
        .iter()
        .map(|r| {
            vec![
                r.app.clone(),
                r.lang.name().to_string(),
                format!("{:.3}", r.baseline_s * 1e3),
                format!("{:.3}", r.final_s * 1e3),
                format!("{:.2}x", r.speedup()),
                format!("{}", r.total_measurements),
            ]
        })
        .collect();
    crate::util::bench::markdown_table(
        &["app", "lang", "CPU ms", "offloaded ms", "speedup", "measurements"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> Config {
        Config::fast_sim()
    }

    #[test]
    fn mm_offload_finds_clone_replacement_and_speedup() {
        let r = offload_workload("mm", Lang::C, fast_cfg()).unwrap();
        assert!(r.final_measurement.ok);
        assert!(r.speedup() > 2.0, "speedup {}", r.speedup());
        // the hand-written matmul nest must be library-replaced
        let fb = r.funcblock.as_ref().unwrap();
        assert!(!fb.chosen.is_empty(), "clone replacement should win");
        assert!(
            r.final_plan
                .regions
                .values()
                .any(|g| matches!(g.exec, crate::vm::RegionExec::Library { .. })),
            "final plan should contain a library region"
        );
    }

    #[test]
    fn smallloops_stays_on_cpu() {
        let r = offload_workload("smallloops", Lang::C, fast_cfg()).unwrap();
        // GA should learn that offloading tiny loops hurts
        assert!(
            r.best_gene.iter().all(|&b| !b),
            "small loops must stay on CPU: {:?}",
            r.best_gene
        );
        assert!((r.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn same_pattern_found_across_languages() {
        // E7: semantically identical apps → same offload decisions
        let mut speedups = Vec::new();
        for lang in Lang::all() {
            let r = offload_workload("blackscholes", lang, fast_cfg()).unwrap();
            assert!(r.final_measurement.ok, "{lang}: {:?}", r.final_measurement.failure);
            speedups.push((lang, r.best_gene.clone(), r.speedup()));
        }
        for w in speedups.windows(2) {
            assert_eq!(w[0].1, w[1].1, "{} vs {} gene mismatch", w[0].0, w[1].0);
            assert!((w[0].2 - w[1].2).abs() < 1e-9, "speedups differ");
        }
    }

    #[test]
    fn fourier_uses_name_matched_library() {
        let r = offload_workload("fourier", Lang::Java, fast_cfg()).unwrap();
        assert!(r.final_plan.gpu_calls.contains("dft"), "dft should be GPU-replaced");
        assert!(r.speedup() > 1.5, "speedup {}", r.speedup());
    }

    #[test]
    fn annotated_source_contains_directives() {
        let r = offload_workload("blackscholes", Lang::C, fast_cfg()).unwrap();
        assert!(
            r.annotated_source.contains("#pragma acc"),
            "annotated source should carry OpenACC directives:\n{}",
            r.annotated_source
        );
        let rp = offload_workload("blackscholes", Lang::Python, fast_cfg()).unwrap();
        assert!(rp.annotated_source.contains("# [pycuda]"));
    }

    #[test]
    fn adaptive_target_selection_picks_many_core_for_small_loops() {
        // small parallel loops: many-core (no transfers, cheap entry) must
        // beat the GPU; heavy compute prefers the GPU
        let src = crate::workloads::get("smallloops", Lang::C).unwrap();
        let r = offload_adaptive(
            src.code,
            Lang::C,
            "smallloops",
            &fast_cfg(),
            &crate::device::TargetKind::all(),
        )
        .unwrap();
        assert_eq!(r.per_target.len(), 3);
        // every target at least matches CPU (GA keeps the all-zero gene)
        for (t, rep) in &r.per_target {
            assert!(rep.speedup() >= 0.999, "{t}: {}", rep.speedup());
        }
        let heavy = crate::workloads::get("blackscholes", Lang::C).unwrap();
        let r2 = offload_adaptive(
            heavy.code,
            Lang::C,
            "blackscholes",
            &fast_cfg(),
            &crate::device::TargetKind::all(),
        )
        .unwrap();
        // on the heavy elementwise app the accelerators must beat many-core
        let get = |t: crate::device::TargetKind| {
            r2.per_target.iter().find(|(x, _)| *x == t).unwrap().1.final_s
        };
        assert!(
            get(crate::device::TargetKind::Gpu) < get(crate::device::TargetKind::ManyCore),
            "GPU should win on heavy elementwise work"
        );
    }

    #[test]
    fn batch_offload_parallel_matches_sequential() {
        let reqs: Vec<BatchRequest> = ["smallloops", "mixed", "fourier"]
            .iter()
            .flat_map(|app| Lang::all().map(|l| BatchRequest::workload(app, l).unwrap()))
            .collect();
        let seq = offload_batch(&reqs, 1, &fast_cfg());
        let par = offload_batch(&reqs, 4, &fast_cfg());
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.app, b.app);
            assert_eq!(a.best_gene, b.best_gene, "{}", a.app);
            assert!((a.final_s - b.final_s).abs() < 1e-15);
        }
    }

    #[test]
    fn report_json_is_well_formed() {
        let r = offload_workload("smallloops", Lang::Python, fast_cfg()).unwrap();
        let s = r.to_json().to_string();
        assert!(s.contains("\"app\":\"smallloops\""));
        assert!(s.contains("\"speedup\":"));
    }
}
