fn main() {
    envadapt::cli::main();
}
