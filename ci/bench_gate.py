#!/usr/bin/env python3
"""Bench regression gate.

Compares a fresh bench JSON against the committed baseline and fails
when throughput (evals/sec) regressed by more than the threshold on any
row. Covers both bench files: ``BENCH_engine.json`` (rows keyed by
``workers``; ``cargo bench -- engine``) and ``BENCH_vm.json`` (rows
keyed by ``workload``; ``cargo bench -- vm``).

A placeholder baseline (``evals_per_sec: null`` — committed before the
first toolchain-equipped run) skips the gate for that row, so the gate
arms itself automatically once real numbers land in the repository.

Usage: bench_gate.py BASELINE.json FRESH.json [--threshold 0.25]
"""

import json
import sys

THRESHOLD = 0.25  # fail when fresh < (1 - THRESHOLD) * baseline


def row_key(r):
    # BENCH_engine.json rows are per worker count, BENCH_vm.json rows per
    # workload family; either value is a stable row identity
    return r.get("workers") if r.get("workers") is not None else r.get("workload")


def rows(doc):
    return {row_key(r): r.get("evals_per_sec") for r in doc.get("results", [])}


def main(argv):
    if len(argv) < 3:
        sys.exit(__doc__)
    threshold = THRESHOLD
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])
    with open(argv[1]) as f:
        baseline = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)
    base_rows, fresh_rows = rows(baseline), rows(fresh)
    if not base_rows:
        sys.exit("baseline has no results[] — malformed bench JSON")
    bench = baseline.get("bench", "bench")

    failures = []
    gated = 0
    for key in sorted(base_rows, key=str):
        base_eps = base_rows[key]
        fresh_eps = fresh_rows.get(key)
        if base_eps is None:
            print(f"{key}: baseline pending (placeholder) — gate skipped")
            continue
        if fresh_eps is None:
            failures.append(f"{key}: missing from fresh results")
            continue
        gated += 1
        ratio = fresh_eps / base_eps
        status = "OK" if ratio >= 1.0 - threshold else "REGRESSION"
        print(
            f"{key}: {base_eps:.1f} -> {fresh_eps:.1f} evals/sec "
            f"({ratio:.2f}x) {status}"
        )
        if status == "REGRESSION":
            failures.append(
                f"{key}: throughput fell to {ratio:.2f}x of baseline "
                f"(limit {1.0 - threshold:.2f}x)"
            )

    if failures:
        sys.exit(f"{bench} regression gate FAILED:\n  " + "\n  ".join(failures))
    if gated:
        print(f"{bench} within {threshold:.0%} of baseline ({gated} rows gated)")
    else:
        print(f"no armed baseline rows — commit the fresh {bench} JSON to arm the gate")


if __name__ == "__main__":
    main(sys.argv)
