#!/usr/bin/env python3
"""Bench regression gate.

Compares a fresh bench JSON against the committed baseline and fails
when throughput regressed by more than the threshold on any row. Covers
the six bench files: ``BENCH_engine.json`` (rows keyed by ``workers``,
valued in ``evals_per_sec``; ``cargo bench -- engine``),
``BENCH_vm.json`` (rows keyed by ``workload``, valued in
``evals_per_sec``; ``cargo bench -- vm``), ``BENCH_serve.json``
(rows keyed by ``clients``, valued in ``requests_per_sec``;
``cargo bench -- serve``), ``BENCH_patterndb.json`` (rows keyed by
``records``, valued in ``lookups_per_sec``; ``cargo bench --
patterndb``), ``BENCH_transfer.json`` (rows keyed by ``workload``,
valued in ``plans_per_sec``; ``cargo bench -- transfer``) and
``BENCH_router.json`` (rows keyed by ``shards``, valued in
``requests_per_sec``; ``cargo bench -- router``).

For ``patterndb_lookup`` the gate additionally asserts *flatness* on the
fresh run: per-lookup throughput across the record-count rows (10k →
1M) must stay within ``FLAT_RATIO`` of each other — the indexed, tiered
DB's whole point is that lookups do not degrade as the DB grows.

A placeholder baseline (a ``null`` throughput — committed before the
first toolchain-equipped run) skips the gate for that row, so the gate
arms itself automatically once real numbers land in the repository.
(The flatness check runs off the *fresh* values, so it arms as soon as
the bench itself produces numbers.)

Usage: bench_gate.py BASELINE.json FRESH.json [--threshold 0.25]
"""

import json
import sys

THRESHOLD = 0.25  # fail when fresh < (1 - THRESHOLD) * baseline
FLAT_RATIO = 5.0  # patterndb_lookup: max/min lookups_per_sec across sizes


def row_key(r):
    # BENCH_engine.json rows are per worker count, BENCH_vm.json rows per
    # workload family, BENCH_serve.json rows per concurrent-client count,
    # BENCH_patterndb.json rows per record count, BENCH_router.json rows
    # per shard count; any of those values is a stable row identity
    for key in ("workers", "workload", "clients", "records", "shards"):
        if r.get(key) is not None:
            return r.get(key)
    return None


def row_value(r):
    # engine/vm rows carry evals_per_sec, serve rows requests_per_sec,
    # patterndb rows lookups_per_sec, transfer rows plans_per_sec
    if "lookups_per_sec" in r:
        return r.get("lookups_per_sec")
    if "requests_per_sec" in r:
        return r.get("requests_per_sec")
    if "plans_per_sec" in r:
        return r.get("plans_per_sec")
    return r.get("evals_per_sec")


def rows(doc):
    return {row_key(r): row_value(r) for r in doc.get("results", [])}


def main(argv):
    if len(argv) < 3:
        sys.exit(__doc__)
    threshold = THRESHOLD
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])
    with open(argv[1]) as f:
        baseline = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)
    base_rows, fresh_rows = rows(baseline), rows(fresh)
    if not base_rows:
        sys.exit("baseline has no results[] — malformed bench JSON")
    bench = baseline.get("bench", "bench")

    failures = []
    gated = 0
    for key in sorted(base_rows, key=str):
        base_eps = base_rows[key]
        fresh_eps = fresh_rows.get(key)
        if base_eps is None:
            print(f"{key}: baseline pending (placeholder) — gate skipped")
            continue
        if fresh_eps is None:
            failures.append(f"{key}: missing from fresh results")
            continue
        gated += 1
        ratio = fresh_eps / base_eps
        status = "OK" if ratio >= 1.0 - threshold else "REGRESSION"
        print(
            f"{key}: {base_eps:.1f} -> {fresh_eps:.1f} per sec "
            f"({ratio:.2f}x) {status}"
        )
        if status == "REGRESSION":
            failures.append(
                f"{key}: throughput fell to {ratio:.2f}x of baseline "
                f"(limit {1.0 - threshold:.2f}x)"
            )

    # flat-latency assertion: lookup throughput must not fall off as the
    # record count grows (fresh values; skipped while still placeholders)
    if fresh.get("bench") == "patterndb_lookup":
        vals = [v for v in rows(fresh).values() if v is not None]
        if len(vals) >= 2 and len(vals) == len(fresh.get("results", [])):
            flat = max(vals) / min(vals)
            if flat > FLAT_RATIO:
                failures.append(
                    f"lookup throughput varies {flat:.2f}x across record counts "
                    f"(flatness limit {FLAT_RATIO:.1f}x) — per-lookup latency "
                    f"is no longer flat in the DB size"
                )
            else:
                print(f"flatness: {flat:.2f}x spread across sizes (limit {FLAT_RATIO:.1f}x)")
        else:
            print("flatness: fresh results still placeholders — check skipped")

    if failures:
        sys.exit(f"{bench} regression gate FAILED:\n  " + "\n  ".join(failures))
    if gated:
        print(f"{bench} within {threshold:.0%} of baseline ({gated} rows gated)")
    else:
        print(f"no armed baseline rows — commit the fresh {bench} JSON to arm the gate")


if __name__ == "__main__":
    main(sys.argv)
