#!/usr/bin/env python3
"""Bench regression gate.

Compares a fresh bench JSON against the committed baseline and fails
when throughput regressed by more than the threshold on any row. Covers
the three bench files: ``BENCH_engine.json`` (rows keyed by ``workers``,
valued in ``evals_per_sec``; ``cargo bench -- engine``),
``BENCH_vm.json`` (rows keyed by ``workload``, valued in
``evals_per_sec``; ``cargo bench -- vm``) and ``BENCH_serve.json``
(rows keyed by ``clients``, valued in ``requests_per_sec``;
``cargo bench -- serve``).

A placeholder baseline (a ``null`` throughput — committed before the
first toolchain-equipped run) skips the gate for that row, so the gate
arms itself automatically once real numbers land in the repository.

Usage: bench_gate.py BASELINE.json FRESH.json [--threshold 0.25]
"""

import json
import sys

THRESHOLD = 0.25  # fail when fresh < (1 - THRESHOLD) * baseline


def row_key(r):
    # BENCH_engine.json rows are per worker count, BENCH_vm.json rows per
    # workload family, BENCH_serve.json rows per concurrent-client count;
    # any of those values is a stable row identity
    for key in ("workers", "workload", "clients"):
        if r.get(key) is not None:
            return r.get(key)
    return None


def row_value(r):
    # engine/vm rows carry evals_per_sec, serve rows requests_per_sec
    if "requests_per_sec" in r:
        return r.get("requests_per_sec")
    return r.get("evals_per_sec")


def rows(doc):
    return {row_key(r): row_value(r) for r in doc.get("results", [])}


def main(argv):
    if len(argv) < 3:
        sys.exit(__doc__)
    threshold = THRESHOLD
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])
    with open(argv[1]) as f:
        baseline = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)
    base_rows, fresh_rows = rows(baseline), rows(fresh)
    if not base_rows:
        sys.exit("baseline has no results[] — malformed bench JSON")
    bench = baseline.get("bench", "bench")

    failures = []
    gated = 0
    for key in sorted(base_rows, key=str):
        base_eps = base_rows[key]
        fresh_eps = fresh_rows.get(key)
        if base_eps is None:
            print(f"{key}: baseline pending (placeholder) — gate skipped")
            continue
        if fresh_eps is None:
            failures.append(f"{key}: missing from fresh results")
            continue
        gated += 1
        ratio = fresh_eps / base_eps
        status = "OK" if ratio >= 1.0 - threshold else "REGRESSION"
        print(
            f"{key}: {base_eps:.1f} -> {fresh_eps:.1f} per sec "
            f"({ratio:.2f}x) {status}"
        )
        if status == "REGRESSION":
            failures.append(
                f"{key}: throughput fell to {ratio:.2f}x of baseline "
                f"(limit {1.0 - threshold:.2f}x)"
            )

    if failures:
        sys.exit(f"{bench} regression gate FAILED:\n  " + "\n  ".join(failures))
    if gated:
        print(f"{bench} within {threshold:.0%} of baseline ({gated} rows gated)")
    else:
        print(f"no armed baseline rows — commit the fresh {bench} JSON to arm the gate")


if __name__ == "__main__":
    main(sys.argv)
