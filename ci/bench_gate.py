#!/usr/bin/env python3
"""Engine-bench regression gate.

Compares a fresh ``BENCH_engine.json`` (written by ``cargo bench --
engine``) against the committed baseline and fails when measurement
throughput (evals/sec) regressed by more than the threshold at any
worker count.

A placeholder baseline (``evals_per_sec: null`` — committed before the
first toolchain-equipped run) skips the gate for that row, so the gate
arms itself automatically once real numbers land in the repository.

Usage: bench_gate.py BASELINE.json FRESH.json [--threshold 0.25]
"""

import json
import sys

THRESHOLD = 0.25  # fail when fresh < (1 - THRESHOLD) * baseline


def rows(doc):
    return {r.get("workers"): r.get("evals_per_sec") for r in doc.get("results", [])}


def main(argv):
    if len(argv) < 3:
        sys.exit(__doc__)
    threshold = THRESHOLD
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])
    with open(argv[1]) as f:
        baseline = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)
    base_rows, fresh_rows = rows(baseline), rows(fresh)
    if not base_rows:
        sys.exit("baseline has no results[] — malformed BENCH_engine.json")

    failures = []
    gated = 0
    for workers in sorted(base_rows):
        base_eps = base_rows[workers]
        fresh_eps = fresh_rows.get(workers)
        if base_eps is None:
            print(f"workers={workers}: baseline pending (placeholder) — gate skipped")
            continue
        if fresh_eps is None:
            failures.append(f"workers={workers}: missing from fresh results")
            continue
        gated += 1
        ratio = fresh_eps / base_eps
        status = "OK" if ratio >= 1.0 - threshold else "REGRESSION"
        print(
            f"workers={workers}: {base_eps:.1f} -> {fresh_eps:.1f} evals/sec "
            f"({ratio:.2f}x) {status}"
        )
        if status == "REGRESSION":
            failures.append(
                f"workers={workers}: throughput fell to {ratio:.2f}x of baseline "
                f"(limit {1.0 - threshold:.2f}x)"
            )

    if failures:
        sys.exit("engine bench regression gate FAILED:\n  " + "\n  ".join(failures))
    if gated:
        print(f"engine throughput within {threshold:.0%} of baseline ({gated} rows gated)")
    else:
        print("no armed baseline rows — commit the fresh BENCH_engine.json to arm the gate")


if __name__ == "__main__":
    main(sys.argv)
