#!/usr/bin/env python3
"""Docs link gate.

Walks the repository's markdown (README.md, docs/*.md, rust/DESIGN.md,
and anything else passed on the command line), extracts every inline
markdown link, and fails when a *relative* link points at a file that
does not exist (resolved against the linking file's directory) or at a
heading anchor the target file does not define. External links
(http/https/mailto) are not fetched — this gate is offline and only
keeps the repo-internal documentation web from rotting as files move.

Anchor checking uses the GitHub slug rule: lowercase, spaces to dashes,
punctuation dropped (a close-enough approximation that has no false
negatives on plain ASCII headings).

Usage: docs_link_gate.py [FILE.md ...]   (no args = the default set)
"""

import os
import re
import sys

DEFAULT_DOCS = [
    "README.md",
    "ROADMAP.md",
    "CHANGES.md",
    "docs/PROTOCOL.md",
    "docs/OPERATIONS.md",
    "rust/DESIGN.md",
]

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def github_slug(heading):
    heading = heading.strip().lower()
    # drop inline-code backticks and markdown emphasis, keep the text
    heading = heading.replace("`", "").replace("*", "")
    out = []
    for ch in heading:
        if ch.isalnum():
            out.append(ch)
        elif ch in (" ", "-"):
            out.append("-")
        # other punctuation is dropped
    return "".join(out)


def anchors_of(path, cache={}):
    if path not in cache:
        with open(path, encoding="utf-8") as f:
            text = f.read()
        cache[path] = {github_slug(h) for h in HEADING_RE.findall(text)}
    return cache[path]


def check_file(md_path, repo_root):
    failures = []
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    base = os.path.dirname(md_path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            resolved = os.path.normpath(os.path.join(base, path_part))
            if not os.path.exists(resolved):
                failures.append(f"{md_path}: broken link -> {target}")
                continue
            anchor_target = resolved
        else:
            anchor_target = md_path  # same-file anchor
        if anchor and anchor_target.endswith(".md"):
            if github_slug(anchor) not in anchors_of(anchor_target):
                failures.append(
                    f"{md_path}: missing anchor -> {target} "
                    f"(no heading slugs to '{anchor}' in {anchor_target})"
                )
    return failures


def main(argv):
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    docs = argv[1:] or [
        os.path.join(repo_root, d) for d in DEFAULT_DOCS if os.path.exists(os.path.join(repo_root, d))
    ]
    failures = []
    checked = 0
    for doc in docs:
        checked += 1
        failures.extend(check_file(doc, repo_root))
    if failures:
        sys.exit("docs link gate FAILED:\n  " + "\n  ".join(failures))
    print(f"docs link gate OK ({checked} files, no broken relative links)")


if __name__ == "__main__":
    main(sys.argv)
