#!/usr/bin/env python3
"""Test-count regression gate.

Sums the `test result: ok. N passed; M failed; ...` lines of a captured
`cargo test` run and fails when the total number of passing tests drops
below the committed seed count — a deleted or silently-skipped test suite
is a regression even when everything that still runs is green.

Usage: test_count_gate.py CARGO_TEST_OUTPUT BASELINE_FILE

BASELINE_FILE holds the seed count: the first non-comment token is the
minimum allowed total of passing tests (`#` starts a comment). Ratchet it
upward when a PR adds tests; never lower it without a removal rationale.
"""

import re
import sys


def read_baseline(path: str) -> int:
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.split("#", 1)[0].strip()
            if line:
                return int(line)
    raise SystemExit(f"{path}: no baseline count found")


def main() -> None:
    if len(sys.argv) != 3:
        raise SystemExit(__doc__)
    out_path, baseline_path = sys.argv[1], sys.argv[2]
    with open(out_path, encoding="utf-8", errors="replace") as fh:
        text = fh.read()
    results = re.findall(
        r"test result: (\w+)\. (\d+) passed; (\d+) failed", text
    )
    if not results:
        raise SystemExit(
            f"{out_path}: no `test result:` lines found — did `cargo test` run?"
        )
    passed = sum(int(p) for _, p, _ in results)
    failed = sum(int(f) for _, _, f in results)
    baseline = read_baseline(baseline_path)
    print(
        f"test-count gate: {len(results)} suites, {passed} passed, "
        f"{failed} failed (seed count {baseline})"
    )
    if failed:
        raise SystemExit(f"{failed} tests failed")
    if any(status != "ok" for status, _, _ in results):
        raise SystemExit("a test suite did not finish ok")
    if passed < baseline:
        raise SystemExit(
            f"test count regression: {passed} passing tests < seed count "
            f"{baseline} — a suite disappeared or tests were deleted"
        )
    print("test-count gate OK")


if __name__ == "__main__":
    main()
