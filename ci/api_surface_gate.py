#!/usr/bin/env python3
"""Exported-API surface gate for the versioned offload API.

Snapshots every `pub` item declaration of `rust/src/api.rs` (and any
`rust/src/api/` submodules) and compares it against the committed seed
`ci/api_surface_seed.txt`. The api module is the crate's documented
embedding surface and the source of the wire protocol's canonical
encoding, so any change to it — adding, removing or re-signaturing a
public item — must be deliberate: update the seed in the same PR (and
bump `SCHEMA_VERSION` / extend docs/PROTOCOL.md when the wire encoding
is affected).

Usage:
    api_surface_gate.py CRATE_DIR SEED_FILE           # compare (CI)
    api_surface_gate.py CRATE_DIR SEED_FILE --update  # rewrite the seed

CRATE_DIR is the rust crate root (the directory holding src/api.rs).
"""

import pathlib
import re
import sys

# One normalized line per exported item. Multi-line signatures are folded
# to the declaration head — enough to catch additions, removals and
# renames without re-implementing a Rust parser.
PUB_ITEM = re.compile(
    r"^\s*pub\s+(?:(?:unsafe|async|extern\s+\"[^\"]*\")\s+)*"
    r"(fn|struct|enum|const|static|trait|type|mod|use)\s+(.+)$"
)


def surface_of(path: pathlib.Path) -> list[str]:
    items = []
    for raw in path.read_text(encoding="utf-8").splitlines():
        m = PUB_ITEM.match(raw)
        if not m:
            continue
        kind, rest = m.group(1), m.group(2)
        # fold the declaration to its head: stop at the body/terminator
        rest = re.split(r"[{;=]", rest, maxsplit=1)[0]
        rest = re.sub(r"\s+", " ", rest).strip().rstrip(",(")
        items.append(f"pub {kind} {rest}")
    return items


def collect(crate_dir: pathlib.Path) -> list[str]:
    files = []
    single = crate_dir / "src" / "api.rs"
    if single.exists():
        files.append(single)
    subdir = crate_dir / "src" / "api"
    if subdir.is_dir():
        files.extend(sorted(subdir.rglob("*.rs")))
    if not files:
        raise SystemExit(f"no api module found under {crate_dir}/src")
    out = []
    for f in files:
        rel = f.relative_to(crate_dir)
        for item in surface_of(f):
            out.append(f"{rel}: {item}")
    return sorted(out)


def main() -> None:
    args = [a for a in sys.argv[1:] if a != "--update"]
    update = "--update" in sys.argv[1:]
    if len(args) != 2:
        raise SystemExit(__doc__)
    crate_dir, seed_path = pathlib.Path(args[0]), pathlib.Path(args[1])
    current = collect(crate_dir)

    if update:
        header = (
            "# Exported surface of the versioned offload API (rust/src/api.rs),\n"
            "# snapshotted by ci/api_surface_gate.py. CI fails when the live\n"
            "# surface differs — regenerate deliberately with:\n"
            "#   python3 ci/api_surface_gate.py rust ci/api_surface_seed.txt --update\n"
        )
        seed_path.write_text(header + "\n".join(current) + "\n", encoding="utf-8")
        print(f"api-surface gate: seed updated ({len(current)} items)")
        return

    seed = [
        line
        for line in seed_path.read_text(encoding="utf-8").splitlines()
        if line.strip() and not line.lstrip().startswith("#")
    ]
    added = sorted(set(current) - set(seed))
    removed = sorted(set(seed) - set(current))
    print(f"api-surface gate: {len(current)} exported items (seed {len(seed)})")
    if added or removed:
        for line in added:
            print(f"  + {line}")
        for line in removed:
            print(f"  - {line}")
        raise SystemExit(
            "the exported envadapt::api surface changed — if intentional, "
            "regenerate the seed (see ci/api_surface_gate.py --update) and "
            "review docs/PROTOCOL.md + SCHEMA_VERSION in the same PR"
        )
    print("api-surface gate OK")


if __name__ == "__main__":
    main()
