"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (and data) so the kernels are exercised at both
the MXU-tiled multiples-of-128 sizes and ragged single-block sizes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, mm, reduction, ref, spectral, stencil

SET = settings(max_examples=12, deadline=None)


def rnd(shape, seed, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape) * scale, jnp.float32
    )


class TestMatmul:
    @SET
    @given(n=st.sampled_from([4, 16, 31, 64, 128, 256]), seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, seed):
        a, b = rnd((n, n), seed), rnd((n, n), seed + 1)
        got = mm.matmul(a, b)
        np.testing.assert_allclose(got, ref.matmul(a, b), rtol=5e-4, atol=5e-4)

    def test_identity(self):
        n = 64
        eye = jnp.eye(n, dtype=jnp.float32)
        b = rnd((n, n), 7)
        np.testing.assert_allclose(mm.matmul(eye, b), b, rtol=1e-6)

    def test_block_selection(self):
        assert mm.block_for(256) == 128
        assert mm.block_for(100) == 100
        assert mm.vmem_bytes(128) == 3 * 128 * 128 * 4


class TestSaxpy:
    @SET
    @given(
        n=st.sampled_from([8, 100, 1024, 4096]),
        alpha=st.floats(-10, 10, allow_nan=False),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, n, alpha, seed):
        x, y = rnd(n, seed), rnd(n, seed + 1)
        got = elementwise.saxpy(alpha, x, y)
        want = ref.saxpy(jnp.float32(alpha), x, y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_zero_alpha_is_identity(self):
        y = rnd(1024, 3)
        np.testing.assert_allclose(elementwise.saxpy(0.0, rnd(1024, 2), y), y)


class TestDft:
    @SET
    @given(n=st.sampled_from([8, 32, 100, 128, 256]), seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, seed):
        re, im = rnd(n, seed), rnd(n, seed + 1)
        got_re, got_im = spectral.dft(re, im)
        want_re, want_im = ref.dft(re, im)
        np.testing.assert_allclose(got_re, want_re, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(got_im, want_im, rtol=2e-3, atol=2e-3)

    def test_constant_signal_concentrates_at_dc(self):
        n = 64
        re, im = jnp.ones(n, jnp.float32), jnp.zeros(n, jnp.float32)
        got_re, got_im = spectral.dft(re, im)
        assert abs(float(got_re[0]) - n) < 1e-3
        assert np.abs(np.asarray(got_re[1:])).max() < 1e-3
        assert np.abs(np.asarray(got_im)).max() < 1e-3

    def test_parseval(self):
        n = 128
        re, im = rnd(n, 5), rnd(n, 6)
        fr, fi = spectral.dft(re, im)
        lhs = float((fr**2 + fi**2).sum()) / n
        rhs = float((re**2 + im**2).sum())
        assert abs(lhs - rhs) / rhs < 1e-3


class TestBlackScholes:
    @SET
    @given(n=st.sampled_from([16, 100, 1024]), seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, seed):
        g = np.random.default_rng(seed)
        s = jnp.asarray(g.uniform(10, 200, n), jnp.float32)
        k = jnp.asarray(g.uniform(10, 200, n), jnp.float32)
        t = jnp.asarray(g.uniform(0.05, 3.0, n), jnp.float32)
        gc, gp = elementwise.blackscholes(s, k, t)
        wc, wp = ref.blackscholes(s, k, t)
        np.testing.assert_allclose(gc, wc, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gp, wp, rtol=1e-4, atol=1e-4)

    def test_put_call_parity(self):
        n = 256
        g = np.random.default_rng(0)
        s = jnp.asarray(g.uniform(50, 150, n), jnp.float32)
        k = jnp.asarray(g.uniform(50, 150, n), jnp.float32)
        t = jnp.asarray(g.uniform(0.1, 2.0, n), jnp.float32)
        c, p = elementwise.blackscholes(s, k, t)
        parity = np.asarray(c - p - (s - k * jnp.exp(-0.02 * t)))
        assert np.abs(parity).max() < 1e-3


class TestStencil:
    @SET
    @given(n=st.sampled_from([4, 16, 64, 128]), seed=st.integers(0, 2**16))
    def test_jacobi_matches_ref(self, n, seed):
        src = rnd((n, n), seed)
        np.testing.assert_allclose(
            stencil.jacobi_step(src), ref.jacobi_step(src), rtol=1e-5, atol=1e-6
        )

    def test_jacobi_boundary_fixed(self):
        src = rnd((16, 16), 1)
        out = stencil.jacobi_step(src)
        np.testing.assert_array_equal(out[0], src[0])
        np.testing.assert_array_equal(out[-1], src[-1])
        np.testing.assert_array_equal(out[:, 0], src[:, 0])

    @SET
    @given(
        n=st.sampled_from([32, 100, 1039]),
        m=st.sampled_from([3, 8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_conv1d_matches_ref(self, n, m, seed):
        x, k = rnd(n, seed), rnd(m, seed + 1)
        np.testing.assert_allclose(
            stencil.conv1d(x, k), ref.conv1d(x, k), rtol=2e-4, atol=2e-4
        )


class TestReduce:
    @SET
    @given(n=st.sampled_from([8, 100, 1024, 4096]), seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, seed):
        x = rnd(n, seed)
        got = reduction.reduce_sum(x)
        np.testing.assert_allclose(got, ref.reduce_sum(x), rtol=1e-4, atol=1e-3)

    def test_sum_of_ones(self):
        x = jnp.ones(2048, jnp.float32)
        assert float(reduction.reduce_sum(x)) == pytest.approx(2048.0)
