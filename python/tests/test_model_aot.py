"""L2 model catalogue + AOT lowering tests.

Checks every ARTIFACTS entry traces, produces the declared output arity,
and lowers to HLO text the xla 0.5.1 text parser conventions require
(`ENTRY`, tuple root). A sampled artifact is lowered end-to-end to verify
the text is stable and non-trivial.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import ref


def test_catalogue_is_complete():
    names = set(model.ARTIFACTS)
    for expected in (
        "matmul_64",
        "matmul_128",
        "dft_256",
        "saxpy_4096",
        "blackscholes_4096",
        "jacobi_64",
        "conv1d_1024",
        "reduce_4096",
        "pipeline_64",
    ):
        assert expected in names, expected


@pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
def test_artifact_traces_and_output_arity(name):
    fn, example = model.ARTIFACTS[name]
    outs = jax.eval_shape(fn, *example)
    assert isinstance(outs, tuple) and len(outs) >= 1
    for o in outs:
        assert o.dtype == jnp.float32


@pytest.mark.parametrize("name", ["matmul_64", "dft_128", "reduce_1024"])
def test_lowering_produces_hlo_text(name):
    fn, example = model.ARTIFACTS[name]
    text = to_hlo_text(jax.jit(fn).lower(*example))
    assert "ENTRY" in text
    assert "f32" in text
    assert len(text) > 500


def test_pipeline_composition_matches_ref():
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    (got,) = model.gpu_pipeline(a, b, x)
    want = ref.pipeline(a, b, x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_matmul_sizes_match_workload_catalogue():
    # The Rust coordinator dispatches matmul_<n> for these n; keep in sync
    # with rust/src/workloads.rs.
    for n in (32, 64, 96, 128, 256):
        fn, example = model.ARTIFACTS[f"matmul_{n}"]
        assert example[0].shape == (n, n)
