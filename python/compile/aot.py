"""AOT lowering: every artifact in `model.ARTIFACTS` → HLO *text*.

HLO text (not `.serialize()`d protos) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
(what the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only NAME]

Also writes `manifest.txt` — one line per artifact:
    <name> <num_inputs> <num_outputs> <in_shape>,... -> <out_shape>,...
(human-readable; the Rust runtime keys on file names and checks arity).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ARTIFACTS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_str(x) -> str:
    return "x".join(str(d) for d in x.shape) or "scalar"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single artifact by name")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name, (fn, example) in sorted(ARTIFACTS.items()):
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = fn(*example)
        ins = ",".join(shape_str(x) for x in example)
        os_ = ",".join(shape_str(x) for x in outs)
        manifest.append(f"{name} {len(example)} {len(outs)} {ins} -> {os_}")
        print(f"  {name}: {len(text)} chars, in [{ins}] out [{os_}]")

    if not args.only:
        with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
