"""Layer-2 model: the GPU library's artifact catalogue.

For this paper the "model" is the library of offload targets: every
function block the pattern DB can replace (cuBLAS/cuFFT analogues) plus a
composite pipeline proving kernel composition in one lowered module.
`ARTIFACTS` maps artifact name → (jax function, example inputs); `aot.py`
lowers each entry to `artifacts/<name>.hlo.txt` for the Rust runtime.

Artifact naming convention (parsed by `rust/src/runtime`):
    <kernel>_<n>.hlo.txt
where `<n>` is the size parameter the Rust coordinator keys on (square
matrix extent, vector length, or grid rows).
"""

import jax.numpy as jnp

from .kernels import elementwise, mm, reduction, spectral, stencil


def _f32(shape):
    return jnp.zeros(shape, jnp.float32)


def gpu_matmul(a, b):
    """Square matmul through the Pallas MXU kernel."""
    return (mm.matmul(a, b),)


def gpu_dft(re, im):
    """DFT through the Pallas twiddle-matmul kernel."""
    return tuple(spectral.dft(re, im))


def gpu_saxpy(alpha, x, y):
    return (elementwise.saxpy(alpha, x, y),)


def gpu_blackscholes(s, k, t):
    return tuple(elementwise.blackscholes(s, k, t))


def gpu_jacobi(src):
    return (stencil.jacobi_step(src),)


def gpu_conv1d(x, k):
    return (stencil.conv1d(x, k),)


def gpu_reduce(x):
    return (reduction.reduce_sum(x),)


def gpu_pipeline(a, b, x):
    """Composite: matmul → saxpy on row 0 → reduce (single HLO module)."""
    (c,) = gpu_matmul(a, b)
    (y,) = gpu_saxpy(jnp.float32(0.5), c[0], x)
    (s,) = gpu_reduce(y)
    return (s,)


#: artifact name → (fn, example_args); sizes match `rust/src/workloads.rs`
ARTIFACTS = {}

for n in (32, 64, 96, 128, 256):
    ARTIFACTS[f"matmul_{n}"] = (gpu_matmul, (_f32((n, n)), _f32((n, n))))
for n in (128, 256, 512):
    ARTIFACTS[f"dft_{n}"] = (gpu_dft, (_f32((n,)), _f32((n,))))
for n in (1024, 4096, 65536):
    ARTIFACTS[f"saxpy_{n}"] = (
        gpu_saxpy,
        (jnp.zeros((1,), jnp.float32), _f32((n,)), _f32((n,))),
    )
for n in (1024, 4096, 65536):
    ARTIFACTS[f"blackscholes_{n}"] = (
        gpu_blackscholes,
        (_f32((n,)), _f32((n,)), _f32((n,))),
    )
for n in (32, 64, 128):
    ARTIFACTS[f"jacobi_{n}"] = (gpu_jacobi, (_f32((n, n)),))
for n in (1024, 4096):
    # conv input is n+15 so the valid output is exactly n
    ARTIFACTS[f"conv1d_{n}"] = (gpu_conv1d, (_f32((n + 15,)), _f32((16,))))
for n in (1024, 4096, 65536):
    ARTIFACTS[f"reduce_{n}"] = (gpu_reduce, (_f32((n,)),))
ARTIFACTS["pipeline_64"] = (gpu_pipeline, (_f32((64, 64)), _f32((64, 64)), _f32((64,))))
