"""Pure-jnp oracle implementations of the GPU library kernels.

These are the correctness references for the Pallas kernels (pytest
compares each kernel against these under hypothesis-driven shape sweeps)
and the semantic twins of the Rust CPU library in ``rust/src/libs.rs``
(the paper's PCAST results check compares the two across the PJRT
boundary).

All kernels are f32, matching the device-side representation.
"""

import jax.numpy as jnp
from jax.scipy.stats import norm


def matmul(a, b):
    """c = a @ b for square f32 matrices."""
    return jnp.matmul(a, b)


def dft(re, im):
    """Dense DFT of a complex signal given as separate re/im vectors.

    Returns (re_out, im_out). Matches the naive O(n^2) definition used by
    the Rust CPU library (cuFFT analogue at small n).
    """
    n = re.shape[0]
    k = jnp.arange(n, dtype=jnp.float32)[:, None]
    t = jnp.arange(n, dtype=jnp.float32)[None, :]
    ang = -2.0 * jnp.pi * k * t / n
    c, s = jnp.cos(ang), jnp.sin(ang)
    re_out = c @ re - s @ im
    im_out = s @ re + c @ im
    return re_out, im_out


def saxpy(alpha, x, y):
    """y' = alpha*x + y."""
    return alpha * x + y


def conv1d(x, k):
    """Valid 1-D correlation: y[i] = sum_j x[i+j] * k[j]."""
    n, m = x.shape[0], k.shape[0]
    idx = jnp.arange(n - m + 1)[:, None] + jnp.arange(m)[None, :]
    return (x[idx] * k[None, :]).sum(axis=1)


def reduce_sum(x):
    """Scalar sum (kept 0-d so the HLO output is a scalar)."""
    return jnp.sum(x)


def blackscholes(s, k, t, r=0.02, sigma=0.30):
    """European call/put prices; fixed r/sigma match the Rust library."""
    sq = sigma * jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (r + 0.5 * sigma * sigma) * t) / sq
    d2 = d1 - sq
    disc = jnp.exp(-r * t)
    call = s * norm.cdf(d1) - k * disc * norm.cdf(d2)
    put = k * disc * norm.cdf(-d2) - s * norm.cdf(-d1)
    return call, put


def jacobi_step(src):
    """One 5-point Jacobi relaxation step; boundary rows/cols copied."""
    interior = 0.25 * (
        src[:-2, 1:-1] + src[2:, 1:-1] + src[1:-1, :-2] + src[1:-1, 2:]
    )
    return src.at[1:-1, 1:-1].set(interior)


def pipeline(a, b, x):
    """Composite 'mixed' workload: c = a@b; y = 0.5*c[0]+x; return sum(y).

    Exercises kernel composition in a single lowered module (the L2 model
    role: several kernels composed into one HLO graph).
    """
    c = matmul(a, b)
    y = saxpy(jnp.float32(0.5), c[0], x)
    return reduce_sum(y)
