"""Pallas kernel library — the GPU side of the pattern DB.

Each module provides one family of device kernels, all `interpret=True`
(see mm.py for why), plus `ref.py`, the pure-jnp oracle used by pytest.
"""

from . import elementwise, mm, reduction, ref, spectral, stencil  # noqa: F401
