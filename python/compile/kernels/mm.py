"""Layer-1 Pallas matmul kernel (the cuBLAS-gemm analogue).

TPU adaptation of the paper's CUDA library replacement (DESIGN.md
§Hardware-Adaptation): instead of thread-block shared-memory tiles, the
HBM→VMEM schedule is expressed with `BlockSpec`s over a (i, j, k) grid and
the inner block product targets the MXU systolic array
(128×128 f32 blocks; VMEM footprint per step = 3 × 128×128×4 B = 192 KiB,
well under the ~16 MiB VMEM budget, leaving room for double buffering).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU lowering would only change `interpret`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MXU_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Grid (i, j, k): accumulate x[i,k] @ y[k,j] into o[i,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def block_for(n: int) -> int:
    """MXU-sized blocks when the extent allows, whole-array otherwise."""
    return MXU_BLOCK if n % MXU_BLOCK == 0 else n


@functools.partial(jax.jit, static_argnames=())
def matmul(a, b):
    """c = a @ b for square f32 matrices via the Pallas kernel."""
    n = a.shape[0]
    assert a.shape == (n, n) and b.shape == (n, n), (a.shape, b.shape)
    bm = bn = bk = block_for(n)
    grid = (n // bm, n // bn, n // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_bytes(n: int) -> int:
    """Estimated VMEM footprint of one grid step (for DESIGN.md §Perf)."""
    b = block_for(n)
    return 3 * b * b * 4
