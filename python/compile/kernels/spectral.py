"""Layer-1 Pallas DFT kernel (the cuFFT analogue of the pattern DB).

The O(n²) DFT is expressed as two matrix-vector products against twiddle
matrices. TPU adaptation: the twiddle rows stream through VMEM in
MXU-friendly row blocks; the signal vector stays resident. Twiddles are
computed *inside* the lowered function (jnp on iota), so the HLO artifact
needs only (re, im) inputs — the GPU generates its own constants, exactly
like a cuFFT plan.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _dft_kernel(c_ref, s_ref, re_ref, im_ref, re_o_ref, im_o_ref):
    c, s = c_ref[...], s_ref[...]
    re, im = re_ref[...], im_ref[...]
    re_o_ref[...] = c @ re - s @ im
    im_o_ref[...] = s @ re + c @ im


@jax.jit
def dft(re, im):
    """(re_out, im_out) = DFT(re + i·im)."""
    n = re.shape[0]
    k = jnp.arange(n, dtype=jnp.float32)[:, None]
    t = jnp.arange(n, dtype=jnp.float32)[None, :]
    ang = -2.0 * jnp.pi * k * t / n
    c, s = jnp.cos(ang), jnp.sin(ang)
    b = ROW_BLOCK if n % ROW_BLOCK == 0 else n
    return pl.pallas_call(
        _dft_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, n), lambda i: (i, 0)),
            pl.BlockSpec((b, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(c, s, re, im)
