"""Layer-1 Pallas tree-reduction kernel (sum).

TPU adaptation: CUDA's warp-shuffle tree reduction becomes a sequential
grid over VPU-width chunks with a (1, 1) accumulator block that persists
across grid steps (TPU grids execute in order, so cross-step accumulation
is well-defined — the idiom Pallas documents for reductions).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 1024


def _reduce_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].sum()


@jax.jit
def reduce_sum(x):
    """Scalar sum of a 1-D f32 vector."""
    n = x.shape[0]
    c = CHUNK if n % CHUNK == 0 else n
    out = pl.pallas_call(
        _reduce_kernel,
        grid=(n // c,),
        in_specs=[pl.BlockSpec((c,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x)
    return out[0]
