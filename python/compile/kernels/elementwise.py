"""Layer-1 Pallas elementwise kernels: saxpy and Black-Scholes.

TPU adaptation: 1-D data is processed in VPU-friendly chunks (multiples of
8×128 = 1024 lanes). Scalars ride along as (1,)-blocks broadcast to every
grid step (the paper's kernel-argument transfer: scalars are cheap, arrays
are what the transfer planner worries about).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.scipy.stats import norm

CHUNK = 1024  # 8 sublanes × 128 lanes


def chunk_for(n: int) -> int:
    return CHUNK if n % CHUNK == 0 else n


def _saxpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


@jax.jit
def saxpy(alpha, x, y):
    """y' = alpha*x + y (alpha: scalar or shape-(1,) f32)."""
    n = x.shape[0]
    alpha = jnp.asarray(alpha, jnp.float32).reshape((1,))
    c = chunk_for(n)
    return pl.pallas_call(
        _saxpy_kernel,
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(alpha, x, y)


def _blackscholes_kernel(s_ref, k_ref, t_ref, call_ref, put_ref, *, r, sigma):
    s, k, t = s_ref[...], k_ref[...], t_ref[...]
    sq = sigma * jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (r + 0.5 * sigma * sigma) * t) / sq
    d2 = d1 - sq
    disc = jnp.exp(-r * t)
    call_ref[...] = s * norm.cdf(d1) - k * disc * norm.cdf(d2)
    put_ref[...] = k * disc * norm.cdf(-d2) - s * norm.cdf(-d1)


@jax.jit
def blackscholes(s, k, t):
    """European option prices; fixed r=0.02, sigma=0.30 (see libs.rs)."""
    n = s.shape[0]
    c = chunk_for(n)
    kernel = functools.partial(_blackscholes_kernel, r=0.02, sigma=0.30)
    return pl.pallas_call(
        kernel,
        grid=(n // c,),
        in_specs=[pl.BlockSpec((c,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((c,), lambda i: (i,))] * 2,
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(s, k, t)
