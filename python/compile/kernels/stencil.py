"""Layer-1 Pallas stencil kernels: 2-D Jacobi step and valid 1-D conv.

TPU adaptation: halo exchange between thread blocks (the CUDA formulation)
becomes whole-array VMEM residency — the grids used by the paper-scale
workloads (≤ 256²·4 B = 256 KiB) fit VMEM outright, so the kernel reads
the full array block and the `BlockSpec` machinery degenerates to a single
grid step. For larger grids the row-block + halo variant would partition
rows; the single-block form keeps the artifact exact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(src_ref, dst_ref):
    src = src_ref[...]
    interior = 0.25 * (
        src[:-2, 1:-1] + src[2:, 1:-1] + src[1:-1, :-2] + src[1:-1, 2:]
    )
    dst_ref[...] = src_ref[...]
    dst_ref[1:-1, 1:-1] = interior


@jax.jit
def jacobi_step(src):
    """One 5-point relaxation step, boundary copied."""
    n, m = src.shape
    return pl.pallas_call(
        _jacobi_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(src)


def _conv1d_kernel(x_ref, k_ref, o_ref, *, m):
    x, k = x_ref[...], k_ref[...]
    n_out = o_ref.shape[0]
    idx = jnp.arange(n_out)[:, None] + jnp.arange(m)[None, :]
    o_ref[...] = (x[idx] * k[None, :]).sum(axis=1)


@jax.jit
def conv1d(x, k):
    """Valid correlation y[i] = Σ_j x[i+j]·k[j]; output length n-m+1."""
    n, m = x.shape[0], k.shape[0]
    kernel = functools.partial(_conv1d_kernel, m=m)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n - m + 1,), jnp.float32),
        interpret=True,
    )(x, k)
