//! END-TO-END DRIVER (E1 / Fig. 1): the full environment-adaptive flow on
//! every built-in workload in every source language, against the real
//! PJRT-backed device (AOT Pallas/XLA artifacts on the request path).
//!
//! ```bash
//! make artifacts && cargo run --release --example full_pipeline
//! ```
//!
//! Prints the E1/E3 table recorded in EXPERIMENTS.md and a JSON log per
//! offload. All layers compose here: C/Python/Java front ends → IR →
//! analysis → function-block + GA search → VM + device model → PJRT
//! executables compiled from `artifacts/*.hlo.txt`.

use envadapt::config::Config;
use envadapt::coordinator::{markdown_summary, Coordinator};
use envadapt::ir::Lang;
use envadapt::util::stats::geomean;
use envadapt::workloads;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut c = Coordinator::new(Config::standard());
    println!(
        "device: {}",
        if c.device_is_pjrt() {
            "PJRT CPU client, real AOT artifacts"
        } else {
            "simulated (run `make artifacts` first for the full stack)"
        }
    );

    let mut reports = Vec::new();
    for app in workloads::APPS {
        for lang in Lang::all() {
            let src = workloads::get(app, lang).unwrap();
            let r = c.offload_source(src.code, lang, app)?;
            assert!(
                r.final_measurement.ok,
                "{app} [{lang}] failed the results check: {:?}",
                r.final_measurement.failure
            );
            println!("{}", r.summary());
            reports.push(r);
        }
    }

    println!("\n=== E1: end-to-end offload, every app × language ===\n");
    println!("{}", markdown_summary(&reports));

    let speedups: Vec<f64> = reports.iter().map(|r| r.speedup()).collect();
    println!("geomean speedup: {:.2}x over {} offloads", geomean(&speedups), reports.len());
    println!(
        "total search wall time: {:.1}s ({} measurements)",
        t0.elapsed().as_secs_f64(),
        reports.iter().map(|r| r.total_measurements).sum::<usize>()
    );

    // JSON log (machine-readable record for EXPERIMENTS.md tooling)
    let log: Vec<String> = reports.iter().map(|r| r.to_json().to_string()).collect();
    let path = "target/full_pipeline_log.jsonl";
    std::fs::write(path, log.join("\n") + "\n")?;
    println!("wrote {path}");
    Ok(())
}
