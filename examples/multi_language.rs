//! Multi-language demo — the paper's core claim (§3.3): the *same* common
//! offload pipeline handles C, Python, Java and JavaScript, and finds the
//! *same* offload pattern for semantically identical applications.
//!
//! ```bash
//! cargo run --release --example multi_language [app]
//! ```

use envadapt::config::Config;
use envadapt::coordinator::Coordinator;
use envadapt::ir::Lang;
use envadapt::workloads;

fn main() -> anyhow::Result<()> {
    let app = std::env::args().nth(1).unwrap_or_else(|| "blackscholes".to_string());
    let mut c = Coordinator::new(Config::standard());
    println!("offloading `{app}` from every source language\n");

    let mut rows = Vec::new();
    for lang in Lang::all() {
        let src = workloads::get(&app, lang)
            .ok_or_else(|| anyhow::anyhow!("unknown workload {app:?}"))?;
        let r = c.offload_source(src.code, lang, &app)?;
        println!("{}", r.summary());
        let gene: String = r.best_gene.iter().map(|&b| if b { '1' } else { '0' }).collect();
        rows.push((lang, gene, r.final_plan.gpu_calls.len(), r.speedup()));
    }

    println!("\nlanguage-independence check:");
    println!("  {:<8} {:<16} {:<14} {:<10}", "lang", "gene", "gpu lib calls", "speedup");
    for (lang, gene, libs, speedup) in &rows {
        println!("  {:<8} {:<16} {:<14} {:.2}x", lang.name(), gene, libs, speedup);
    }
    let all_same = rows.windows(2).all(|w| w[0].1 == w[1].1 && w[0].2 == w[1].2);
    println!(
        "\n→ {}",
        if all_same {
            "identical offload pattern found from all four front ends ✓"
        } else {
            "patterns differ across languages ✗ (this should not happen)"
        }
    );
    Ok(())
}
