//! VM interpreter micro-benchmark (the §Perf L3 hot path).
//!
//! ```bash
//! cargo run --release --example vmbench
//! ```
//! Reports the best-of-10 interpretation rate on three profiles: the
//! elementwise/intrinsic-heavy `blackscholes`, the index-heavy `mm`,
//! and the nested-loop `stencil`.

use envadapt::frontend::parse;
use envadapt::ir::Lang;
use envadapt::vm::{run_cpu, VmConfig};
use envadapt::workloads;

fn bench(app: &str) {
    let src = workloads::get(app, Lang::C).unwrap();
    let p = parse(src.code, Lang::C, app).unwrap();
    let mut best = f64::INFINITY;
    let mut ops = 0;
    for _ in 0..10 {
        let t0 = std::time::Instant::now();
        let o = run_cpu(&p, VmConfig::default()).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        ops = o.cpu_ops;
        best = best.min(dt);
    }
    println!(
        "{app:<14} ops={ops:>9}  best wall={:>8.3}ms  rate={:>6.1} Mops/s",
        best * 1e3,
        ops as f64 / best / 1e6
    );
}

fn main() {
    bench("blackscholes");
    bench("mm");
    bench("stencil");
}
