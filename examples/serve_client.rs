//! Offload-as-a-service quickstart: spawn the service in-process, then
//! talk to it over TCP exactly as an external client would (the same
//! wire protocol `envadapt serve` exposes).
//!
//! Demonstrates the learning pattern DB: the first round of requests
//! runs real searches; the second round replays every pattern from the
//! DB with **zero** new measurements.
//!
//! ```bash
//! cargo run --release --example serve_client
//! # against an external server instead:
//! #   envadapt serve --sim --port 7747 &
//! #   cargo run --release --example serve_client -- 127.0.0.1:7747
//! ```

use envadapt::config::Config;
use envadapt::ir::Lang;
use envadapt::proto::{self, Response};
use envadapt::server::{self, ServeOptions};
use envadapt::workloads;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() -> anyhow::Result<()> {
    // spawn an in-process server unless an address was given
    let external = std::env::args().nth(1);
    let (addr, handle) = match &external {
        Some(a) => (a.parse()?, None),
        None => {
            let h = server::spawn_tcp(
                Config::fast_sim(),
                ServeOptions { pool: 2, db_path: None, ..Default::default() },
                "127.0.0.1:0",
            )?;
            (h.addr(), Some(h))
        }
    };
    println!("offload service at {addr}\n");

    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut id = 0i64;
    let mut roundtrip = |line: &str| -> anyhow::Result<Response> {
        writer.write_all(line.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        Response::parse_line(&resp)
    };

    for round in 1..=2 {
        println!("-- round {round} --");
        for lang in Lang::all() {
            let code = workloads::get("mm", lang).unwrap().code;
            id += 1;
            let r = roundtrip(&proto::offload_request(id, "mm", lang, code))?;
            anyhow::ensure!(r.ok, "offload failed: {:?}", r.error);
            let rep = r.report().expect("offload report");
            let f = |k: &str| rep.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let m = rep.get("measurements").and_then(|v| v.as_i64()).unwrap_or(-1);
            let reused = rep
                .get("pattern_reuse")
                .and_then(|v| v.as_str())
                .map(|s| format!("pattern DB: {s}"))
                .unwrap_or_else(|| "full search".to_string());
            println!(
                "  mm [{lang:<6}] speedup {:>6.2}x  {m:>3} measurements  ({reused})",
                f("speedup")
            );
        }
    }

    id += 1;
    let stats = roundtrip(&format!("{{\"op\":\"stats\",\"id\":{id}}}"))?;
    println!("\nservice stats: {}", stats.body.get("stats").unwrap().to_pretty());

    // disconnect, then shut down the server if we spawned it ourselves
    // (shutdown drains open connections before returning)
    drop(roundtrip);
    drop(reader);
    drop(writer);
    if let Some(h) = handle {
        h.shutdown()?;
        println!("service shut down cleanly");
    }
    Ok(())
}
