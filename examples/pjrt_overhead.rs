//! PJRT executable-cache micro-benchmark (§Perf, runtime layer).
//!
//! Measures first-call (HLO-text parse + XLA compile + run) vs cached-call
//! latency for the matmul_64 artifact — the justification for the
//! compile-once executable cache in `runtime::Runtime`.

fn main() {
    let mut rt = envadapt::runtime::Runtime::new(envadapt::runtime::Runtime::artifact_dir()).unwrap();
    let n = 64;
    let a = vec![1.0f32; n*n];
    let shape = [n, n];
    let t0 = std::time::Instant::now();
    let _ = rt.execute("matmul_64", &[(&shape, &a), (&shape, &a)]).unwrap();
    println!("first call (compile+run): {:.3}ms", t0.elapsed().as_secs_f64()*1e3);
    let mut best = f64::INFINITY;
    for _ in 0..50 {
        let t = std::time::Instant::now();
        let _ = rt.execute("matmul_64", &[(&shape, &a), (&shape, &a)]).unwrap();
        best = best.min(t.elapsed().as_secs_f64());
    }
    println!("cached call best: {:.1}us", best*1e6);
}
