//! Function-block offload demo (§3.2.2): name-matched library calls and
//! Deckard-style clone detection of a hand-written (and then *edited*)
//! matmul, replaced by the GPU library.
//!
//! ```bash
//! cargo run --release --example function_blocks
//! ```

use envadapt::analysis;
use envadapt::clone::{char_vector_stmt, similarity};
use envadapt::config::{Config, FuncBlockConfig};
use envadapt::coordinator::Coordinator;
use envadapt::frontend::parse;
use envadapt::funcblock;
use envadapt::ir::{Lang, Stmt};
use envadapt::patterndb::PatternDb;

/// A program whose author copy-pasted a matmul and edited it (renamed
/// variables, added a scale factor) — the case name matching misses and
/// similarity detection catches.
const EDITED_CLONE: &str = r#"
#include <stdio.h>
void main() {
    int m = 64;
    double p[m][m];
    double q[m][m];
    double r[m][m];
    seed_fill(p, 11);
    seed_fill(q, 22);
    for (int x = 0; x < m; x++) {
        for (int y = 0; y < m; y++) {
            double acc = 0.0;
            for (int z = 0; z < m; z++) {
                acc += p[x][z] * q[z][y];
            }
            r[x][y] = acc;
        }
    }
    double checksum = 0.0;
    for (int x = 0; x < m; x++) {
        for (int y = 0; y < m; y++) {
            checksum += r[x][y];
        }
    }
    printf("%f\n", checksum);
}
"#;

fn main() -> anyhow::Result<()> {
    let prog = parse(EDITED_CLONE, Lang::C, "edited_clone")?;
    let a = analysis::analyze(&prog);
    let db = PatternDb::builtin();

    println!("pattern DB: {} records", db.len());
    for rec in db.records() {
        println!("  {:<14} sizes {:?} — {}", rec.key, rec.sizes, rec.description);
    }

    // show the raw similarity scores per loop nest (Deckard's view)
    println!("\nclone-similarity scores against the matmul comparison code:");
    let mm = db.lookup_name("matmul").unwrap();
    for info in &a.loops {
        if let Some(stmt) = prog.find_for(info.id) {
            if matches!(stmt, Stmt::For { .. }) && info.depth == 0 {
                let v = char_vector_stmt(stmt);
                println!(
                    "  loop nest @{} (induction `{}`): similarity {:.4}",
                    info.id,
                    info.var,
                    similarity(&v, &mm.vector)
                );
            }
        }
    }

    let cands = funcblock::find_candidates(&prog, &a, &db, &FuncBlockConfig::default());
    println!("\ncandidates found:");
    for c in &cands {
        println!("  {}", c.description);
    }

    // full offload: the edited clone must be library-replaced
    let mut coordinator = Coordinator::new(Config::standard());
    let r = coordinator.offload_source(EDITED_CLONE, Lang::C, "edited_clone")?;
    println!("\n{}", r.summary());
    if let Some(fb) = &r.funcblock {
        for &i in &fb.chosen {
            println!("  chose: {}", fb.candidates[i].description);
        }
        println!(
            "  trials: {} subsets measured, best mask wins",
            fb.trials.len()
        );
    }
    println!("\n--- annotated source ---\n{}", r.annotated_source);
    Ok(())
}
