//! Batch request serving: §4.2's "アプリケーションの利用依頼があると" loop —
//! offload requests arrive in bulk and are served by a pool of session
//! workers, each owning its coordinators and device caches, all sharing
//! one measurement cache and one learning pattern DB through
//! [`envadapt::api::OffloadSession`].
//!
//! ```bash
//! cargo run --release --example batch_offload [workers]
//! ```

use envadapt::api::{OffloadRequest, OffloadSession};
use envadapt::config::Config;
use envadapt::ir::Lang;
use envadapt::workloads;

fn main() -> anyhow::Result<()> {
    let workers: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    // every workload in every language = 32 requests — the same typed
    // request the CLI and the serve daemon construct
    let requests: Vec<OffloadRequest> = workloads::APPS
        .iter()
        .flat_map(|app| Lang::all().map(move |l| OffloadRequest::workload(app, l).build()))
        .collect::<Result<_, _>>()?;

    println!("serving {} offload requests on {workers} workers…\n", requests.len());
    let t0 = std::time::Instant::now();
    let cfg = Config::fast_sim(); // per-worker simulated devices (deterministic)
    let results = OffloadSession::new(cfg.clone()).offload_batch(&requests, workers);
    let wall = t0.elapsed().as_secs_f64();

    let mut ok = 0;
    let mut total_measurements = 0;
    for r in &results {
        match r {
            Ok(rep) => {
                println!("{}", rep.summary());
                ok += 1;
                total_measurements += rep.total_measurements;
            }
            Err(e) => println!("FAILED: {e}"),
        }
    }
    println!(
        "\n{ok}/{} succeeded; {total_measurements} verification measurements; {:.2}s wall ({:.1} req/s)",
        results.len(),
        wall,
        results.len() as f64 / wall
    );

    // compare against a single worker for the throughput table
    let t1 = std::time::Instant::now();
    let _ = OffloadSession::new(cfg).offload_batch(&requests, 1);
    let wall1 = t1.elapsed().as_secs_f64();
    println!(
        "1-worker wall {:.2}s → {workers}-worker speedup {:.2}x (host has {} core(s); scaling requires > 1)",
        wall1,
        wall1 / wall,
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    Ok(())
}
