//! A tiny metrics poller for the serve daemon: hits the `metrics` op on
//! an interval and prints one-line summaries — the minimal "exporter"
//! sketch from `docs/OPERATIONS.md`, useful for watching a service drain
//! a backlog or warm its pattern DB in real time.
//!
//! ```bash
//! # against a self-spawned in-process service (generates demo traffic):
//! cargo run --release --example metrics_scrape
//! # against an external server, 1 s interval, 10 scrapes:
//! #   envadapt serve --sim --port 7747 &
//! #   cargo run --release --example metrics_scrape -- 127.0.0.1:7747 1000 10
//! ```

use envadapt::config::Config;
use envadapt::ir::Lang;
use envadapt::proto::{self, Response};
use envadapt::server::{self, ServeOptions};
use envadapt::util::json::Json;
use envadapt::workloads;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn scrape(addr: std::net::SocketAddr, id: i64) -> anyhow::Result<Json> {
    // one short-lived connection per scrape, like an external poller
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{{\"op\":\"metrics\",\"id\":{id}}}\n").as_bytes())?;
    stream.flush()?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    let r = Response::parse_line(&line)?;
    anyhow::ensure!(r.ok, "metrics op failed: {:?}", r.error);
    Ok(r.body.get("metrics").expect("metrics payload").clone())
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let external = args.next();
    let interval_ms: u64 = args.next().and_then(|v| v.parse().ok()).unwrap_or(500);
    let scrapes: usize = args.next().and_then(|v| v.parse().ok()).unwrap_or(6);

    let (addr, handle) = match &external {
        Some(a) => (a.parse()?, None),
        None => {
            let h = server::spawn_tcp(
                Config::fast_sim(),
                ServeOptions { pool: 2, ..Default::default() },
                "127.0.0.1:0",
            )?;
            (h.addr(), Some(h))
        }
    };
    println!("scraping metrics from {addr} every {interval_ms} ms ({scrapes} scrapes)\n");

    // self-spawned mode: put some traffic on the service from a client
    // thread so the counters move while we watch
    let traffic = handle.as_ref().map(|_| {
        std::thread::spawn(move || {
            let Ok(stream) = TcpStream::connect(addr) else { return };
            let mut reader = BufReader::new(stream.try_clone().expect("clone"));
            let mut writer = stream;
            let mut id = 100i64;
            for _ in 0..3 {
                for lang in Lang::all() {
                    let code = workloads::get("mm", lang).unwrap().code;
                    id += 1;
                    let line = proto::offload_request(id, "mm", lang, code);
                    if writer.write_all(line.as_bytes()).is_err() {
                        return;
                    }
                    let _ = writer.write_all(b"\n");
                    let _ = writer.flush();
                    let mut resp = String::new();
                    let _ = reader.read_line(&mut resp);
                }
            }
        })
    });

    let i64_at = |m: &Json, group: &str, leaf: &str| {
        m.get(group).and_then(|g| g.get(leaf)).and_then(|v| v.as_i64()).unwrap_or(0)
    };
    for n in 1..=scrapes {
        let m = scrape(addr, n as i64)?;
        let f = |k: &str| m.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let evals_per_sec = m
            .get("search")
            .and_then(|s| s.get("evals_per_sec"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        println!(
            "[{n:>2}] up {:>6.1}s  req {:>4}  ok {:>4}  busy {:>3}  err {:>3}  \
             offloads {:>4} ({:>3} replayed)  evals/s {:>9.1}  queue {}/{}",
            f("uptime_s"),
            m.get("requests_total").and_then(|v| v.as_i64()).unwrap_or(0),
            i64_at(&m, "responses", "ok"),
            i64_at(&m, "responses", "busy"),
            i64_at(&m, "responses", "error"),
            i64_at(&m, "offloads", "total"),
            i64_at(&m, "offloads", "replayed"),
            evals_per_sec,
            m.get("queue_depth").and_then(|v| v.as_i64()).unwrap_or(0),
            m.get("queue_capacity").and_then(|v| v.as_i64()).unwrap_or(0),
        );
        if n < scrapes {
            std::thread::sleep(Duration::from_millis(interval_ms));
        }
    }

    if let Some(t) = traffic {
        let _ = t.join();
    }
    if let Some(h) = handle {
        h.shutdown()?;
        println!("\nservice shut down cleanly");
    }
    Ok(())
}
