//! Quickstart: automatically offload a small C program to the GPU.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Parses the program, finds parallelizable loops and replaceable function
//! blocks, runs the GA-driven search in the verification environment, and
//! prints the chosen pattern plus the OpenACC-annotated source.

use envadapt::config::Config;
use envadapt::coordinator::Coordinator;
use envadapt::ir::Lang;

const PROGRAM: &str = r#"
#include <stdio.h>
#include <math.h>
void main() {
    int n = 8192;
    double x[n];
    double y[n];
    double z[n];
    for (int i = 0; i < n; i++) {
        x[i] = sin(i * 0.001) * 100.0;
        y[i] = cos(i * 0.002) * 50.0;
    }
    for (int i = 0; i < n; i++) {
        z[i] = sqrt(x[i] * x[i] + y[i] * y[i]);
    }
    double total = 0.0;
    for (int i = 0; i < n; i++) {
        total += z[i];
    }
    printf("%f\n", total);
}
"#;

fn main() -> anyhow::Result<()> {
    let mut c = Coordinator::new(Config::standard());
    println!(
        "device: {}\n",
        if c.device_is_pjrt() {
            "PJRT (AOT Pallas/XLA artifacts)"
        } else {
            "simulated cost model (run `make artifacts` for the real thing)"
        }
    );

    let report = c.offload_source(PROGRAM, Lang::C, "quickstart")?;

    println!("{}", report.summary());
    if let Some(ga) = &report.ga {
        println!("\nGA convergence:");
        for g in &ga.history {
            println!(
                "  gen {:>2}: best {:>9.3} ms   mean {:>9.3} ms   ({} measurements)",
                g.generation,
                g.best_time * 1e3,
                g.mean_time * 1e3,
                g.evaluations
            );
        }
    }
    let gene: String = report.best_gene.iter().map(|&b| if b { '1' } else { '0' }).collect();
    println!("\nbest gene: {gene} over parallelizable loops {:?}", report.gene_loops);
    println!("\n--- OpenACC-annotated source the pattern encodes ---\n");
    println!("{}", report.annotated_source);
    Ok(())
}
