//! Embedding envadapt as a library through the versioned offload API —
//! no CLI, no wire protocol: just [`envadapt::api`].
//!
//! A long-lived [`OffloadSession`] owns the shared measurement cache,
//! the learning pattern DB and the coordinator pool; every request is a
//! typed [`OffloadRequest`] (the same type the CLI and the serve daemon
//! construct), and every report renders to the one canonical,
//! `schema_version`-tagged JSON.
//!
//! ```bash
//! cargo run --release --example library_api
//! ```

use envadapt::api::{OffloadRequest, OffloadSession, SCHEMA_VERSION};
use envadapt::config::Config;
use envadapt::device::TargetKind;
use envadapt::ir::Lang;

const PROGRAM: &str = r#"
void main() {
    int n = 4096;
    double prices[n]; double out[n];
    seed_fill(prices, 11);
    for (int i = 0; i < n; i++) {
        out[i] = prices[i] * 1.07 + 2.5;
    }
    double acc = 0.0;
    for (int i = 0; i < n; i++) { acc += out[i]; }
    printf("%f\n", acc);
}
"#;

fn main() -> anyhow::Result<()> {
    // one session for the life of the embedding application
    let mut session = OffloadSession::new(Config::fast_sim());

    // 1) offload inline source text (any supported language)
    let req = OffloadRequest::source(PROGRAM, Lang::C).name("pricing").build()?;
    let first = session.offload(&req)?;
    println!("first request : {}", first.summary());
    println!("  learned pattern: {}", first.learned_pattern);

    // 2) an identical repeat request replays the learned pattern with
    //    zero new search measurements — the session remembers
    let second = session.offload(&req)?;
    println!("second request: {}", second.summary());
    println!(
        "  replayed: {} ({} search measurements)",
        second.reused_pattern.as_deref().unwrap_or("-"),
        second.total_measurements
    );
    anyhow::ensure!(second.total_measurements == 0, "repeat must replay");

    // 3) the same request type drives mixed-destination placement and
    //    every other knob — all fields defaulted, all validated
    let hetero = OffloadRequest::workload("hetero", Lang::Python)
        .devices(vec![TargetKind::Gpu, TargetKind::ManyCore])
        .power_weight(0.1)
        .build()?;
    let placed = session.offload(&hetero)?;
    println!("mixed request : {}", placed.summary());

    // 4) adaptive target selection is a session method too
    let adaptive = session
        .offload_adaptive(&OffloadRequest::workload("blackscholes", Lang::Java).build()?,
            &TargetKind::all())?;
    println!("adaptive      : best target = {}", adaptive.chosen);

    // 5) one canonical, versioned JSON encoding for every consumer
    let json = first.to_json();
    anyhow::ensure!(
        json.get("schema_version").and_then(|v| v.as_i64()) == Some(SCHEMA_VERSION),
        "report JSON must be versioned"
    );
    println!("\ncanonical report JSON (schema_version {SCHEMA_VERSION}):");
    println!("{}", json.to_pretty());
    Ok(())
}
