//! Sharded-cluster quickstart: three offload daemons behind one
//! wire-v2 router (`envadapt route`), all in-process.
//!
//! The demo drives the four-language conformance twins through the
//! router twice: round 1 runs a real plan search on whichever shard
//! each program's fingerprint homes to; round 2 replays every pattern
//! from the cluster's logical pattern DB with **zero** new
//! measurements — the client never learns there is more than one
//! daemon. It finishes by scraping each shard directly to show where
//! the work actually landed and what fraction of it was replayed.
//!
//! ```bash
//! cargo run --release --example cluster_demo
//! ```

use envadapt::config::Config;
use envadapt::ir::Lang;
use envadapt::proto::{self, Response};
use envadapt::router::{self, RouterOptions};
use envadapt::server::{self, ServeOptions};
use envadapt::workloads;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

const TWINS: [(&str, Lang); 4] = [
    ("mm", Lang::C),
    ("fourier", Lang::Python),
    ("stencil", Lang::Java),
    ("blackscholes", Lang::JavaScript),
];

fn roundtrip(addr: &str, line: &str) -> anyhow::Result<Response> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut resp = String::new();
    BufReader::new(stream).read_line(&mut resp)?;
    Response::parse_line(&resp)
}

fn main() -> anyhow::Result<()> {
    // three independent daemons, each with its own pool and pattern DB
    let mut backends = Vec::new();
    let mut shard_addrs = Vec::new();
    for _ in 0..3 {
        let h = server::spawn_tcp(
            Config::fast_sim(),
            ServeOptions { pool: 2, db_path: None, ..Default::default() },
            "127.0.0.1:0",
        )?;
        shard_addrs.push(h.addr().to_string());
        backends.push(h);
    }
    // the router fronts them as one logical service; anti-entropy runs
    // on its default 500 ms cadence so learned plans replicate live
    let rh = router::spawn_router(
        RouterOptions { shards: shard_addrs.clone(), ..Default::default() },
        "127.0.0.1:0",
    )?;
    let front = rh.addr().to_string();
    println!("3-shard cluster behind router at {front}");
    for (i, a) in shard_addrs.iter().enumerate() {
        println!("  shard {i}: {a}");
    }
    println!();

    let mut id = 0i64;
    for round in 1..=2 {
        println!("-- round {round} --");
        for (app, lang) in TWINS {
            let code = workloads::get(app, lang).unwrap().code;
            id += 1;
            let r = roundtrip(&front, &proto::offload_request(id, app, lang, code))?;
            anyhow::ensure!(r.ok, "offload failed: {:?}", r.error);
            let rep = r.report().expect("offload report");
            let speedup = rep.get("speedup").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
            let m = rep.get("measurements").and_then(|v| v.as_i64()).unwrap_or(-1);
            let how = rep
                .get("pattern_reuse")
                .and_then(|v| v.as_str())
                .map(|s| format!("pattern DB: {s}"))
                .unwrap_or_else(|| "full search".to_string());
            println!(
                "  {app:<13}[{:<10}] speedup {speedup:>6.2}x  {m:>3} measurements  ({how})",
                lang.name()
            );
        }
    }

    // the router's own view: where did the traffic go?
    id += 1;
    let m = roundtrip(&front, &format!("{{\"op\":\"metrics\",\"id\":{id}}}"))?;
    let rv = m
        .body
        .get("metrics")
        .and_then(|j| j.get("router"))
        .expect("router metrics");
    let ri = |k: &str| rv.get(k).and_then(|v| v.as_i64()).unwrap_or(-1);
    println!(
        "\nrouter: {} requests, {} forwarded, {} healthy shards, {} replica merges",
        ri("requests_total"),
        ri("forwarded_total"),
        ri("healthy_shards"),
        ri("replica_merges"),
    );

    // per-shard ground truth: scrape each daemon directly and report its
    // replay ratio — round 2 (and any replicated re-homing) is pure replay
    println!("\nper-shard replay ratios:");
    for (i, addr) in shard_addrs.iter().enumerate() {
        id += 1;
        let m = roundtrip(addr, &format!("{{\"op\":\"metrics\",\"id\":{id}}}"))?;
        let off = m
            .body
            .get("metrics")
            .and_then(|j| j.get("offloads"))
            .expect("shard offload metrics");
        let g = |k: &str| off.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        let ratio = off
            .get("replay_ratio")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        println!(
            "  shard {i}: {} offloads ({} searched, {} replayed) — replay ratio {ratio:.2}",
            g("total"),
            g("searched"),
            g("replayed"),
        );
    }

    // drain the router first (it propagates shutdown to every shard),
    // then join the backends
    rh.shutdown()?;
    for h in backends {
        let _ = h.shutdown();
    }
    println!("\ncluster drained cleanly");
    Ok(())
}
